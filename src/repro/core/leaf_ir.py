"""Leaf-program IR: one compilable representation for every fused variant.

PRs 1-4 grew three separately hand-specialized planner/executor stacks —
the forward ATA flattening, the symm (Gram-backward) flattening and the
trans_a/trans_b matmul paths.  Benson & Ballard ("A Framework for
Practical Parallel Fast Matrix Multiplication") make the observation this
module encodes: a fast-matmul variant is *data* — an algebra table of
(operand quadrants, output quadrants) coefficient rows — fed to one
generic executor.  Arrigoni & Massini's follow-up ("Efficiently
Parallelizable Strassen-Based Multiplication of a Matrix by its
Transpose", 2021) is then just one more recursion over the same tables:
``A A^t`` instead of ``A^t A``.

Two registries drive the compiler:

* **Algebra tables** (:data:`ALGEBRAS`, :func:`register_algebra`) — the
  per-level *multiplication* expansion rules.  Each table is a tuple of
  rows ``(a_quads, b_quads, dest_quads)`` with entries
  ``(row, col, coeff)`` over an ``<m, k, n>`` block grid (``dims``,
  default the square ``<2, 2, 2>``).  strassen / winograd / classical
  ship registered, plus the Benson-Ballard-style rectangular base cases
  ``bb322`` (<3,2,2>, 11 products) and ``bb422`` (<4,2,2>, 14 products)
  for tall-skinny operands.  Registration runs a levels=1 numeric
  identity check against the dense oracle, so a structurally valid but
  algebraically wrong table is rejected up front (DESIGN.md §12).

* **Gram algebras** (:data:`GRAM_ALGEBRAS`,
  :func:`register_gram_algebra`) — the *symmetric* recursion itself as a
  table: which 2x2 sub-block combinations recurse as Grams (``sym``
  products, ``G(combo)``) and which multiply generally (``mm``
  products, expanded by the algebra table), with per-destination
  rational coefficients and transpose flags.  ``strassen`` is the
  classic ``G(l) = 4 G(l-1) + 2 t^(l-1)`` split; ``dps`` is a real
  5-product scheme with the Dumas-Pernet-Sedoglavic recursion shape
  ``G(l) = 2 G(l-1) + 3 t^(l-1)`` (arXiv 2001.04109) — a strictly lower
  leaf count than strassen-gram at every level.

The IR then has three layers:

* **LeafProgram** (:func:`compile_program`) — a *kind* (``ata`` |
  ``aat`` | ``matmul`` | ``symm`` | ``rank_k``) recursively flattened
  against the tables into leaf ops.  Every operand term is a uniform
  4-tuple ``(row, col, coeff, trans)`` naming a **stored** leaf block of
  the operand plus a per-term transpose/mirror flag; every destination
  is ``(di, dj, coeff, trans)`` — ``trans`` places the product
  transposed (Gram off-diagonal symmetry; only gram kinds emit it).
  Whole-operand properties (storage layout, operand-level transpose,
  which input the side reads) live on :class:`OperandSpec`; output
  packing and the accumulate flag live on :class:`OutputSpec`.  The
  executor in ``kernels/strassen_fused.py`` binds a program to tile
  sizes and lowers it to scalar-prefetch tables for ONE generic
  ``pallas_call``.

* **Interpreter** (:func:`interpret_program`) — a dense numpy evaluation
  of a program, the parity oracle the Pallas executor (and the property
  suite) is checked against.

Kinds:

``ata``     C = tril(A^t A)       — paper Alg. 1 (column gram).
``aat``     C = tril(A A^t)       — Arrigoni-Massini 2021 (row gram).
``matmul``  C = op(A) @ op(B)     — level-capped fast matmul; the
            ``trans_a``/``trans_b`` variants are the same op list with
            the OperandSpec transposes set (terms always name stored
            blocks, so the executor folds the swap into its index maps).
``symm``    D = X @ Sym           — Sym symmetric, stored as its lower
            triangle only; upper-triangle terms are mirrored onto the
            stored triangle with the per-term trans flag set.
``rank_k``  C += A^t A            — the ``ata`` program with the output
            accumulate flag: the executor seeds each output tile from
            the incoming packed stack instead of zero, so streamed Gram
            chunks never re-materialize C.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "ALGEBRAS", "register_algebra", "get_algebra", "algebra_dims",
    "registered_algebras",
    "GRAM_ALGEBRAS", "register_gram_algebra", "get_gram_algebra",
    "registered_gram_algebras",
    "OperandSpec", "OutputSpec", "LeafOp", "Contribution", "LeafProgram",
    "PROGRAM_KINDS", "compile_program", "interpret_program",
]

# A term is (row_block, col_block, coeff, trans) over the leaf grid of
# the STORED operand; trans = 1 means the leaf is read transposed
# (symm: the term was mirrored onto the stored lower triangle).  Coeffs
# are small rationals — the classic tables use only +-1, the dps gram
# algebra needs +-1/2 and +-1/4.
Term = Tuple[int, int, float, int]
# A destination is (dest_row_block, dest_col_block, coeff, trans);
# trans = 1 places the product transposed (gram kinds only).
Dest = Tuple[int, int, float, int]

PROGRAM_KINDS = ("ata", "aat", "matmul", "symm", "rank_k")


# ---------------------------------------------------------------------------
# Algebra-table registry (Benson-Ballard: variants are data, not code)
# ---------------------------------------------------------------------------

# Strassen's 7 products, matching strassen.py (incl. the M7 sign erratum
# fix recorded in DESIGN.md §9: second operand of M7 is B21 + B22).
_STRASSEN = (
    # M1 = (A11 + A22)(B11 + B22) -> C11 + C22
    (((0, 0, 1), (1, 1, 1)), ((0, 0, 1), (1, 1, 1)), ((0, 0, 1), (1, 1, 1))),
    # M2 = (A21 + A22) B11 -> C21 - C22
    (((1, 0, 1), (1, 1, 1)), ((0, 0, 1),), ((1, 0, 1), (1, 1, -1))),
    # M3 = A11 (B12 - B22) -> C12 + C22
    (((0, 0, 1),), ((0, 1, 1), (1, 1, -1)), ((0, 1, 1), (1, 1, 1))),
    # M4 = A22 (B21 - B11) -> C11 + C21
    (((1, 1, 1),), ((1, 0, 1), (0, 0, -1)), ((0, 0, 1), (1, 0, 1))),
    # M5 = (A11 + A12) B22 -> -C11 + C12
    (((0, 0, 1), (0, 1, 1)), ((1, 1, 1),), ((0, 0, -1), (0, 1, 1))),
    # M6 = (A21 - A11)(B11 + B12) -> C22
    (((1, 0, 1), (0, 0, -1)), ((0, 0, 1), (0, 1, 1)), ((1, 1, 1),)),
    # M7 = (A12 - A22)(B21 + B22) -> C11
    (((0, 1, 1), (1, 1, -1)), ((1, 0, 1), (1, 1, 1)), ((0, 0, 1),)),
)

# Winograd's variant (7 mults / 15 adds), destinations expanded from the
# u-term recombination in strassen.py.
_WINOGRAD = (
    # M1 = A11 B11
    (((0, 0, 1),), ((0, 0, 1),),
     ((0, 0, 1), (0, 1, 1), (1, 0, 1), (1, 1, 1))),
    # M2 = A12 B21
    (((0, 1, 1),), ((1, 0, 1),), ((0, 0, 1),)),
    # M3 = (A11 + A12 - A21 - A22) B22
    (((0, 0, 1), (0, 1, 1), (1, 0, -1), (1, 1, -1)), ((1, 1, 1),),
     ((0, 1, 1),)),
    # M4 = A22 (B11 - B12 - B21 + B22)
    (((1, 1, 1),), ((0, 0, 1), (0, 1, -1), (1, 0, -1), (1, 1, 1)),
     ((1, 0, -1),)),
    # M5 = (A21 + A22)(B12 - B11)
    (((1, 0, 1), (1, 1, 1)), ((0, 1, 1), (0, 0, -1)),
     ((0, 1, 1), (1, 1, 1))),
    # M6 = (A21 + A22 - A11)(B11 + B22 - B12)
    (((1, 0, 1), (1, 1, 1), (0, 0, -1)), ((0, 0, 1), (1, 1, 1), (0, 1, -1)),
     ((0, 1, 1), (1, 0, 1), (1, 1, 1))),
    # M7 = (A11 - A21)(B22 - B12)
    (((0, 0, 1), (1, 0, -1)), ((1, 1, 1), (0, 1, -1)),
     ((1, 0, 1), (1, 1, 1))),
)

# Classical 2x2 block multiply in the same representation (8 products).
_CLASSICAL = tuple(
    (((i, k, 1),), ((k, j, 1),), ((i, j, 1),))
    for i in (0, 1) for j in (0, 1) for k in (0, 1)
)


def _rect_classical(dm: int, dk: int, dn: int, rows, cols):
    """Classical products covering A-rows ``rows`` x C-cols ``cols``."""
    return tuple(
        (((i, k, 1),), ((k, j, 1),), ((i, j, 1),))
        for i in rows for j in cols for k in range(dk)
    )


# <3, 2, 2>: Strassen's 7 on the top 2x2 A-rows + 4 classical products
# for row 2 — 11 products, the Hopcroft-Kerr rank for this shape
# (Benson-Ballard, arXiv 1409.2908: rectangular base cases fit
# tall-skinny operands better than repeated square splits).
_BB322 = _STRASSEN + _rect_classical(3, 2, 2, rows=(2,), cols=(0, 1))

# <4, 2, 2>: two Strassen copies on A-row pairs (0,1) and (2,3) — 14
# products vs the classical 16.
_BB422 = _STRASSEN + tuple(
    (tuple((r + 2, c, s) for r, c, s in a_q), b_q,
     tuple((r + 2, c, s) for r, c, s in d_q))
    for a_q, b_q, d_q in _STRASSEN
)

#: name -> algebra table.  Mutated only through :func:`register_algebra`.
ALGEBRAS: Dict[str, tuple] = {}

#: name -> the <m, k, n> split the table describes (A splits m x k,
#: B splits k x n, C splits m x n per recursion level).
_ALGEBRA_DIMS: Dict[str, Tuple[int, int, int]] = {}

#: name -> gram-algebra entry.  Mutated only through
#: :func:`register_gram_algebra`.
GRAM_ALGEBRAS: Dict[str, dict] = {}

#: callbacks run whenever either registry changes — downstream lru
#: caches keyed on the variant/gram name (the executor's scalar-prefetch
#: tables in ``kernels/strassen_fused.py``) register here so a
#: re-registration cannot leave a stale compiled table behind.
_INVALIDATION_HOOKS: list = []


def on_algebra_change(fn) -> None:
    """Register ``fn()`` to run whenever an algebra table is
    (re)registered.  Used by variant-keyed caches downstream."""
    _INVALIDATION_HOOKS.append(fn)


def _invalidate() -> None:
    # re-registration changes what compile_program(levels, name) means —
    # and every downstream cache keyed on the variant/gram name
    if "compile_program" in globals():
        compile_program.cache_clear()
    for fn in _INVALIDATION_HOOKS:
        fn()


def _check_coeff(s, where: str, name: str) -> None:
    if isinstance(s, bool) or not isinstance(s, (int, float)) \
            or not np.isfinite(s) or s == 0:
        raise ValueError(f"coefficient must be a nonzero finite real, "
                         f"got {s!r} in {where} of algebra {name!r}")


def _smoke_check_algebra(name: str, table, dims) -> None:
    """Cheap levels=1 numeric identity check against the dense oracle.

    Scalar blocks suffice: the tables are bilinear with no per-quad
    transposes, so the identity on scalars implies it on matrix blocks.
    """
    dm, dk, dn = dims
    rng = np.random.default_rng(0)
    for _ in range(2):
        a = rng.standard_normal((dm, dk))
        b = rng.standard_normal((dk, dn))
        c = np.zeros((dm, dn))
        for a_q, b_q, d_q in table:
            p = sum(s * a[r, cc] for r, cc, s in a_q) \
                * sum(s * b[r, cc] for r, cc, s in b_q)
            for r, cc, s in d_q:
                c[r, cc] += s * p
        err = float(np.abs(c - a @ b).max())
        if err > 1e-8:
            raise ValueError(
                f"algebra {name!r} fails the levels=1 multiplication "
                f"identity against the dense oracle (max err {err:.3e})")


def register_algebra(name: str, table, *, dims=(2, 2, 2),
                     overwrite: bool = False) -> None:
    """Register an ``<m, k, n>``-recursion algebra table under ``name``.

    ``table`` is a non-empty tuple of rows ``(a_quads, b_quads,
    dest_quads)``; each quad list is a non-empty tuple of
    ``(row, col, coeff)`` entries — ``a_quads`` over the ``m x k`` grid,
    ``b_quads`` over ``k x n``, ``dest_quads`` over ``m x n`` — with
    nonzero real coefficients.  ``dims`` defaults to the square
    ``<2, 2, 2>`` split.  Registration validates the format AND runs a
    levels=1 numeric identity smoke-check against the dense oracle, so
    an algebraically wrong table fails fast with a clear message instead
    of surfacing later as an interpreter/executor parity miss.
    """
    if not overwrite and name in ALGEBRAS:
        raise ValueError(f"algebra {name!r} already registered")
    dims = tuple(int(d) for d in dims)
    if len(dims) != 3 or any(d < 1 for d in dims):
        raise ValueError(f"dims must be three positive ints <m, k, n>, "
                         f"got {dims!r}")
    dm, dk, dn = dims
    table = tuple(table)
    if not table:
        raise ValueError(f"algebra {name!r} table must be non-empty")
    bounds = ((dm, dk), (dk, dn), (dm, dn))
    labels = ("a_quads", "b_quads", "dest_quads")
    for row in table:
        if len(row) != 3:
            raise ValueError(f"algebra row must be (a, b, dest) triple: "
                             f"{row!r}")
        for quads, (rb, cb), lbl in zip(row, bounds, labels):
            if not quads:
                raise ValueError(f"empty {lbl} list in algebra {name!r} "
                                 f"row {row!r}")
            for q in quads:
                if len(q) != 3:
                    raise ValueError(f"quadrant entry must be "
                                     f"(row, col, coeff): {q!r} in {name!r}")
                r, c, s = q
                if not isinstance(r, int) or not isinstance(c, int) \
                        or not (0 <= r < rb) or not (0 <= c < cb):
                    raise ValueError(f"bad quadrant entry {q!r} in {name!r} "
                                     f"(grid is {rb}x{cb} for {lbl})")
                _check_coeff(s, lbl, name)
    norm = tuple(tuple(tuple(map(tuple, q)) for q in (a, b, d))
                 for a, b, d in table)
    _smoke_check_algebra(name, norm, dims)
    ALGEBRAS[name] = norm
    _ALGEBRA_DIMS[name] = dims
    _invalidate()


def get_algebra(name: str) -> tuple:
    try:
        return ALGEBRAS[name]
    except KeyError:
        raise ValueError(
            f"unknown algebra {name!r}; registered: "
            f"{sorted(ALGEBRAS)}") from None


def algebra_dims(name: str) -> Tuple[int, int, int]:
    """The ``<m, k, n>`` per-level split of a registered algebra."""
    get_algebra(name)
    return _ALGEBRA_DIMS[name]


def registered_algebras() -> Tuple[str, ...]:
    return tuple(sorted(ALGEBRAS))


register_algebra("strassen", _STRASSEN)
register_algebra("winograd", _WINOGRAD)
register_algebra("classical", _CLASSICAL)
register_algebra("bb322", _BB322, dims=(3, 2, 2))
register_algebra("bb422", _BB422, dims=(4, 2, 2))


# ---------------------------------------------------------------------------
# Gram-algebra registry: the symmetric recursion itself as data
# ---------------------------------------------------------------------------
#
# A gram algebra describes ONE level of C = Y Y^t over the 2x2 split of
# Y along (gram axis g, other axis o) — the row split for ``aat``, the
# column split for ``ata`` (one table serves both orientations: the
# column gram is the row gram of Y^t, and terms are stored-block
# agnostic until the compiler maps (g, o) onto the stored grid).
#
#   sym products:  (terms, dests)        P = G(sum_k c_k Y[g_k, o_k])
#   mm  products:  (left, right, dests)  P = (sum L)(sum R)^t
#
# ``terms`` entries are (g, o, coeff); ``dests`` entries are
# (di, dj, coeff, trans) over the 2x2 output grid with di >= dj (the
# upper triangle is implied by symmetry of C) — each dest states the
# FULL content of that output block: C[di, dj] += coeff * (P^t if trans
# else P).  Sym products recurse (their dests must have trans=0: a Gram
# is symmetric, so the flag is meaningless); mm products expand through
# the registered multiplication algebra.

_GRAM_STRASSEN = {
    # C11 = G(Y11) + G(Y12); C22 = G(Y21) + G(Y22)
    "sym": (
        (((0, 0, 1),), ((0, 0, 1, 0),)),
        (((0, 1, 1),), ((0, 0, 1, 0),)),
        (((1, 0, 1),), ((1, 1, 1, 0),)),
        (((1, 1, 1),), ((1, 1, 1, 0),)),
    ),
    # C21 = Y21 Y11^t + Y22 Y12^t
    "mm": (
        (((1, 0, 1),), ((0, 0, 1),), ((1, 0, 1, 0),)),
        (((1, 1, 1),), ((0, 1, 1),), ((1, 0, 1, 0),)),
    ),
}

# A real-coefficient 5-product symmetric scheme with the
# Dumas-Pernet-Sedoglavic recursion shape G(l) = 2 G(l-1) + 3 t^(l-1)
# (arXiv 2001.04109; DPS's own 5-product scheme works over fields with
# an i — this is a real rank-5 realization with the same count, found
# by numeric search and verified exactly):
#   G1 = G(Y11),  G2 = G(Y12)
#   M1 = (Y21 + Y11)(Y21 - Y11)^t
#   M2 = (Y22 + Y12)(Y22 - Y12)^t
#   M3 = (Y11 + Y12 + Y21 - Y22)(Y11 - Y12 + Y21 + Y22)^t
#   C11 =  G1 + G2
#   C21 = -G1 + G2 - M1/2 + M2^t/2 + (M3 + M3^t)/4
#   C22 =  G1 + G2 + (M1 + M1^t)/2 + (M2 + M2^t)/2
_GRAM_DPS = {
    "sym": (
        (((0, 0, 1),),
         ((0, 0, 1, 0), (1, 0, -1, 0), (1, 1, 1, 0))),
        (((0, 1, 1),),
         ((0, 0, 1, 0), (1, 0, 1, 0), (1, 1, 1, 0))),
    ),
    "mm": (
        (((1, 0, 1), (0, 0, 1)), ((1, 0, 1), (0, 0, -1)),
         ((1, 0, -0.5, 0), (1, 1, 0.5, 0), (1, 1, 0.5, 1))),
        (((1, 1, 1), (0, 1, 1)), ((1, 1, 1), (0, 1, -1)),
         ((1, 0, 0.5, 1), (1, 1, 0.5, 0), (1, 1, 0.5, 1))),
        (((0, 0, 1), (0, 1, 1), (1, 0, 1), (1, 1, -1)),
         ((0, 0, 1), (0, 1, -1), (1, 0, 1), (1, 1, 1)),
         ((1, 0, 0.25, 0), (1, 0, 0.25, 1))),
    ),
}


def _check_gram_terms(terms, where: str, name: str):
    if not terms:
        raise ValueError(f"empty term list in {where} of gram algebra "
                         f"{name!r}")
    out = []
    for t in terms:
        if len(t) != 3:
            raise ValueError(f"gram term must be (g, o, coeff): {t!r} in "
                             f"{where} of {name!r}")
        g, o, s = t
        if g not in (0, 1) or o not in (0, 1):
            raise ValueError(f"bad gram term {t!r} in {where} of {name!r} "
                             f"(the split is 2x2)")
        _check_coeff(s, where, name)
        out.append((g, o, s))
    return tuple(out)


def _check_gram_dests(dests, where: str, name: str, *, sym: bool):
    if not dests:
        raise ValueError(f"empty dest list in {where} of gram algebra "
                         f"{name!r}")
    out, seen = [], set()
    for d in dests:
        if len(d) != 4:
            raise ValueError(f"gram dest must be (di, dj, coeff, trans): "
                             f"{d!r} in {where} of {name!r}")
        di, dj, s, tr = d
        if di not in (0, 1) or dj not in (0, 1) or di < dj:
            raise ValueError(f"gram dest {d!r} in {where} of {name!r} must "
                             f"lie in the lower triangle (di >= dj)")
        if tr not in (0, 1):
            raise ValueError(f"bad trans flag in gram dest {d!r} of {name!r}")
        if sym and tr:
            raise ValueError(f"sym dest {d!r} in {where} of {name!r} sets "
                             f"trans — a Gram is symmetric, drop the flag")
        _check_coeff(s, where, name)
        if (di, dj, tr) in seen:
            raise ValueError(f"duplicate dest cell {(di, dj, tr)} in "
                             f"{where} of {name!r}; merge the coefficients")
        seen.add((di, dj, tr))
        out.append((di, dj, s, tr))
    return tuple(out)


def _smoke_check_gram(name: str, sym, mm) -> None:
    """Numeric identity check in the row-gram orientation: the table
    applied to random 2x3 quadrants must reproduce tril(Y Y^t)."""
    rng = np.random.default_rng(1)
    x = {(g, o): rng.standard_normal((2, 3)) for g in (0, 1) for o in (0, 1)}
    y = np.block([[x[0, 0], x[0, 1]], [x[1, 0], x[1, 1]]])
    want = y @ y.T
    out = np.zeros((4, 4))

    def place(p, dests):
        for di, dj, s, tr in dests:
            out[di * 2:(di + 1) * 2, dj * 2:(dj + 1) * 2] += \
                s * (p.T if tr else p)

    for terms, dests in sym:
        combo = sum(s * x[g, o] for g, o, s in terms)
        place(combo @ combo.T, dests)
    for lt, rt, dests in mm:
        u = sum(s * x[g, o] for g, o, s in lt)
        v = sum(s * x[g, o] for g, o, s in rt)
        place(u @ v.T, dests)
    err = max(float(np.abs(out[i * 2:(i + 1) * 2, j * 2:(j + 1) * 2]
                           - want[i * 2:(i + 1) * 2, j * 2:(j + 1) * 2]).max())
              for i, j in ((0, 0), (1, 0), (1, 1)))
    if err > 1e-8:
        raise ValueError(
            f"gram algebra {name!r} fails the one-level Y Y^t identity "
            f"against the dense oracle (max err {err:.3e})")


def register_gram_algebra(name: str, *, sym, mm,
                          overwrite: bool = False) -> None:
    """Register a symmetric-recursion (gram) algebra under ``name``.

    ``sym`` is a tuple of ``(terms, dests)`` rows — products that
    recurse as Grams; ``mm`` is a tuple of ``(left, right, dests)`` rows
    — products expanded through the multiplication algebra.  See the
    registry comment above for entry shapes.  Registration validates the
    format and runs a one-level numeric ``Y Y^t`` identity check, then
    invalidates every downstream compiled-table cache.
    """
    if not overwrite and name in GRAM_ALGEBRAS:
        raise ValueError(f"gram algebra {name!r} already registered")
    sym_n, mm_n = [], []
    for i, row in enumerate(tuple(sym)):
        if len(row) != 2:
            raise ValueError(f"sym row must be (terms, dests): {row!r} in "
                             f"{name!r}")
        terms, dests = row
        sym_n.append((_check_gram_terms(terms, f"sym[{i}]", name),
                      _check_gram_dests(dests, f"sym[{i}]", name, sym=True)))
    for i, row in enumerate(tuple(mm)):
        if len(row) != 3:
            raise ValueError(f"mm row must be (left, right, dests): {row!r} "
                             f"in {name!r}")
        lt, rt, dests = row
        mm_n.append((_check_gram_terms(lt, f"mm[{i}].left", name),
                     _check_gram_terms(rt, f"mm[{i}].right", name),
                     _check_gram_dests(dests, f"mm[{i}]", name, sym=False)))
    if not sym_n:
        raise ValueError(f"gram algebra {name!r} needs at least one sym "
                         f"(recursive) product")
    if not mm_n:
        raise ValueError(f"gram algebra {name!r} needs at least one mm "
                         f"product (nothing feeds the off-diagonal)")
    _smoke_check_gram(name, sym_n, mm_n)
    GRAM_ALGEBRAS[name] = {"sym": tuple(sym_n), "mm": tuple(mm_n)}
    _invalidate()


def get_gram_algebra(name: str) -> dict:
    try:
        return GRAM_ALGEBRAS[name]
    except KeyError:
        raise ValueError(
            f"unknown gram algebra {name!r}; registered: "
            f"{sorted(GRAM_ALGEBRAS)}") from None


def registered_gram_algebras() -> Tuple[str, ...]:
    return tuple(sorted(GRAM_ALGEBRAS))


register_gram_algebra("strassen", **_GRAM_STRASSEN)
register_gram_algebra("dps", **_GRAM_DPS)


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OperandSpec:
    """Whole-side properties of one program operand.

    source:    which executor input the side reads (0 = first array,
               1 = second; ``ata``/``aat``/``rank_k`` read the same
               array on both sides).
    layout:    "dense" (a plain (rows, cols) array over the leaf grid)
               or "tri" (the packed lower-triangular tile stack of
               ``kernels/syrk.py`` — terms then carry the mirror flag).
    transpose: the side is *used* transposed: the executor swaps the
               roles of the stored axes in its index maps and flips the
               gathered sum tile-wise in VMEM.  Never set together with
               layout="tri" (tri mirroring is per-term).
    """
    source: int = 0
    layout: str = "dense"
    transpose: bool = False


@dataclass(frozen=True)
class OutputSpec:
    """packing: "tri" = packed lower-triangular tile stack (di >= dj
    always), "dense" = full block grid.  accumulate: seed each output
    tile from an incoming stack (C += ...) instead of zero."""
    packing: str = "dense"
    accumulate: bool = False


@dataclass(frozen=True)
class LeafOp:
    """One leaf product: (signed sum of stored blocks) x (signed sum)."""
    kind: str                 # "syrk" (gram diagonal leaf) | "mm"
    left: Tuple[Term, ...]
    right: Tuple[Term, ...]
    dests: Tuple[Dest, ...]


@dataclass(frozen=True)
class Contribution:
    """One (leaf op, destination) pair — the unit the executor runs.

    Transposed destinations are already normalized away: for gram kinds
    (the only emitters of trans dests) ``(sum L)^t (sum R)`` transposed
    is exactly the straight contribution with the sides swapped, so
    ``left``/``right`` here may be the op's sides exchanged and the
    executor never sees a per-contribution transpose.  ``sign`` is the
    (possibly rational) destination coefficient.
    """
    di: int
    dj: int
    sign: float
    left: Tuple[Term, ...]
    right: Tuple[Term, ...]
    kind: str


@dataclass(frozen=True)
class LeafProgram:
    """A fully flattened schedule over a per-axis leaf-block grid.

    ``dims`` is the registered algebra's per-level ``<m, k, n>`` split,
    so the grid is ``dims[i] ** levels`` blocks per axis —
    ``blocks_m`` x ``blocks_k`` for the stored left operand (before its
    spec transpose), ``blocks_k`` x ``blocks_n`` for the right.  The
    square-split compat surface (``products`` / ``blocks`` /
    ``max_terms`` / ``contributions`` / ``by_dest`` /
    ``max_contributions`` / ``mult_count``) keeps its PR-1 meaning;
    ``blocks`` raises for rectangular programs.  ``gram`` names the
    gram-algebra entry that shaped the symmetric recursion (gram kinds
    only; "strassen" otherwise).
    """
    kind: str
    levels: int
    variant: str
    ops: Tuple[LeafOp, ...]
    left_spec: OperandSpec
    right_spec: OperandSpec
    out_spec: OutputSpec
    dims: Tuple[int, int, int] = (2, 2, 2)
    gram: str = "strassen"
    _cache: Dict[str, object] = field(default_factory=dict, compare=False,
                                      repr=False)

    # -- compat surface (Plan) ---------------------------------------------
    @property
    def products(self) -> Tuple[LeafOp, ...]:
        return self.ops

    @property
    def blocks_m(self) -> int:
        return self.dims[0] ** self.levels

    @property
    def blocks_k(self) -> int:
        return self.dims[1] ** self.levels

    @property
    def blocks_n(self) -> int:
        return self.dims[2] ** self.levels

    @property
    def blocks(self) -> int:
        """Leaf blocks per matrix dimension (square splits only)."""
        if not (self.dims[0] == self.dims[1] == self.dims[2]):
            raise ValueError(
                f"rectangular program (dims {self.dims}) has no uniform "
                f"block count; use blocks_m/blocks_k/blocks_n")
        return self.blocks_m

    @property
    def out_blocks(self) -> Tuple[int, int]:
        """(rows, cols) of the output leaf grid."""
        if self.out_spec.packing == "tri":
            b = self.blocks          # gram kinds are square-split
            return (b, b)
        return (self.blocks_m, self.blocks_n)

    @property
    def max_terms(self) -> int:
        return max(max(len(p.left), len(p.right)) for p in self.ops)

    def contributions(self) -> Tuple[Contribution, ...]:
        """(op, destination) pairs, sorted by destination block.

        Cached per instance (a module-level lru_cache keyed on ``self``
        would pin every program ever compiled for process lifetime —
        autotune sweeps compile many)."""
        cached = self._cache.get("contributions")
        if cached is None:
            out = []
            for p in self.ops:
                for (di, dj, s, tr) in p.dests:
                    if tr:
                        # P^t = ((sum L) . (sum R))^t with the gram
                        # operand specs is the straight product with the
                        # sides swapped — valid because both sides read
                        # the same source with complementary transposes.
                        assert self.left_spec.source == \
                            self.right_spec.source, \
                            "trans dest outside a gram kind"
                        out.append(Contribution(di, dj, s, p.right, p.left,
                                                p.kind))
                    else:
                        out.append(Contribution(di, dj, s, p.left, p.right,
                                                p.kind))
            out.sort(key=lambda c: (c.di, c.dj))
            cached = tuple(out)
            self._cache["contributions"] = cached
        return cached

    def by_dest(self) -> Dict[Tuple[int, int], Tuple[Contribution, ...]]:
        cached = self._cache.get("by_dest")
        if cached is None:
            grouped: Dict[Tuple[int, int], list] = {}
            for c in self.contributions():
                grouped.setdefault((c.di, c.dj), []).append(c)
            cached = {k: tuple(v) for k, v in grouped.items()}
            self._cache["by_dest"] = cached
        return cached

    @property
    def max_contributions(self) -> int:
        return max(len(v) for v in self.by_dest().values())

    def n_dests(self) -> int:
        """Distinct leaf destinations of the output packing."""
        br, bc = self.out_blocks
        return br * (br + 1) // 2 if self.out_spec.packing == "tri" \
            else br * bc

    def dest_index(self, di: int, dj: int) -> int:
        if self.out_spec.packing == "tri":
            return di * (di + 1) // 2 + dj
        return di * self.out_blocks[1] + dj

    def mult_count(self, mb: int, nb: int, kb: Optional[int] = None) -> int:
        """Scalar multiplications the program performs with the given
        leaf shapes.  Gram kinds (``ata``/``rank_k``: A leaves (mb, nb);
        ``aat``: (mb, nb) with the roles of the grids swapped): SYRK
        leaves compute only the lower triangle — the paper's n(n+1)/2
        saving.  ``matmul``: leaves (mb, kb) x (kb, nb).  ``symm``: X
        leaves (mb, nb) against square (nb, nb) leaves of the packed
        operand.  Matches the ``cost_model`` closed forms evaluated with
        ``leaf=0`` at the padded shape (tests/test_properties.py).
        """
        total = 0
        for p in self.ops:
            if p.kind == "syrk":
                if self.kind == "aat":
                    total += nb * mb * (mb + 1) // 2
                else:
                    total += mb * nb * (nb + 1) // 2
            elif self.kind in ("ata", "rank_k"):
                total += nb * mb * nb          # (nb, mb) @ (mb, nb)
            elif self.kind == "aat":
                total += mb * nb * mb          # (mb, nb) @ (nb, mb)
            elif self.kind == "symm":
                total += mb * nb * nb          # (mb, nb) @ (nb, nb)
            else:
                total += mb * (kb if kb is not None else nb) * nb
        return total


# ---------------------------------------------------------------------------
# The compiler: kind x levels x algebra x gram algebra -> LeafProgram
# ---------------------------------------------------------------------------

def _expand(level: int, left, right, dests, kind, transpose_left,
            transpose_right, table, dims, out: List[LeafOp]):
    """Recursively expand a block product ``level`` more times.

    ``transpose_left`` / ``transpose_right``: that side is conceptually
    ``X^t`` while its terms name stored blocks of ``X`` — quadrant
    (qi, qj) of ``X^t`` is stored block (qj, qi), so quadrant bits
    append swapped on that side.  ``dims`` is the table's per-level
    <m, k, n> split; destination refinement of a *transposed* dest
    places sub-product (ci, cj) transposed at (cj, ci) — square output
    splits only, which gram kinds (the only trans-dest emitters)
    guarantee.
    """
    if level <= 0:
        out.append(LeafOp(kind, tuple(left), tuple(right), tuple(dests)))
        return
    dm, dk, dn = dims
    for a_quads, b_quads, d_quads in table:
        nl = []
        for qi, qj, s in a_quads:
            if transpose_left:
                nl.extend((r * dk + qj, c * dm + qi, s0 * s, 0)
                          for r, c, s0, _t in left)
            else:
                nl.extend((r * dm + qi, c * dk + qj, s0 * s, 0)
                          for r, c, s0, _t in left)
        nr = []
        for qi, qj, s in b_quads:
            if transpose_right:
                nr.extend((r * dn + qj, c * dk + qi, s0 * s, 0)
                          for r, c, s0, _t in right)
            else:
                nr.extend((r * dk + qi, c * dn + qj, s0 * s, 0)
                          for r, c, s0, _t in right)
        nd = []
        for ci, cj, s in d_quads:
            for di, dj, s0, dtr in dests:
                if dtr:
                    assert dm == dn, "trans dest under a rectangular split"
                    nd.append((di * dm + cj, dj * dn + ci, s0 * s, 1))
                else:
                    nd.append((di * dm + ci, dj * dn + cj, s0 * s, 0))
        _expand(level - 1, nl, nr, nd, kind, transpose_left,
                transpose_right, table, dims, out)


def _merge_cells(cells):
    """Sum coefficients of duplicate (di, dj[, tr]) cells, drop zeros."""
    agg: Dict[tuple, float] = {}
    order: List[tuple] = []
    for entry in cells:
        key, c = entry[:-1] if len(entry) == 3 else (entry[0], entry[1],
                                                     entry[3]), entry[2]
        key = tuple(key)
        if key not in agg:
            order.append(key)
            agg[key] = 0
        agg[key] += c
    return [(key, agg[key]) for key in order if agg[key] != 0]


def _compile_gram(levels: int, table, galg, *,
                  rows: bool) -> Tuple[LeafOp, ...]:
    """Flatten the symmetric recursion against a registered gram algebra.

    The gram algebra is stated over the 2x2 (gram axis g, other axis o)
    split of ``C = Y Y^t``; ``rows=True`` (AAT) maps a combo term
    (g, o) onto stored block (g, o) of A, ``rows=False`` (ATA — the
    column gram is the row gram of A^t) onto stored block (o, g).  The
    recursion carries *placements*: (gi, gj, coeff) positions of the
    current node's Gram in the depth-level output grid, always in the
    lower triangle.  An off-diagonal placement (gi != gj) needs the FULL
    Gram content, so lower-triangle gram-algebra dests gain their
    mirrored (transposed for mm products, identical for sym — a Gram is
    symmetric) upper-counterpart placements; a diagonal placement only
    ever refines to positions whose strictly-upper leaf dests are
    provably redundant mirrors and are filtered at the end.

    mm products expand through the multiplication ``table``; the level-0
    value convention is ``(sum L)(sum R)^t`` on the gram axis, which the
    operand specs realize in both orientations with left = L, right = R
    (ATA: left transposed -> (sum L)^t (sum R) over stored blocks).
    """
    ops: List[LeafOp] = []

    def stored(g: int, o: int) -> Tuple[int, int]:
        return (g, o) if rows else (o, g)

    def node(terms, depth: int, placements):
        if depth == levels:
            ts = tuple((*stored(g, o), c, 0) for g, o, c in terms)
            dests = tuple((gi, gj, c, 0)
                          for (gi, gj), c in _merge_cells(
                              [(gi, gj, c) for gi, gj, c in placements]))
            assert dests, "sym placements cancelled to zero"
            ops.append(LeafOp("syrk", ts, ts, dests))
            return
        for s_terms, s_dests in galg["sym"]:
            child_terms = [(g * 2 + qg, o * 2 + qo, c * qc)
                           for g, o, c in terms for qg, qo, qc in s_terms]
            child_pl = []
            for gi, gj, pc in placements:
                full = gi != gj
                for di, dj, dc, _tr in s_dests:
                    child_pl.append((gi * 2 + di, gj * 2 + dj, pc * dc))
                    if full and di != dj:
                        # mirrored placement of a symmetric Gram block
                        child_pl.append((gi * 2 + dj, gj * 2 + di, pc * dc))
            child_pl = [(gi, gj, c)
                        for (gi, gj), c in _merge_cells(child_pl)]
            assert child_pl, "sym placements cancelled to zero"
            node(child_terms, depth + 1, child_pl)
        for l_terms, r_terms, m_dests in galg["mm"]:
            left = [(*stored(g * 2 + qg, o * 2 + qo), c * qc, 0)
                    for g, o, c in terms for qg, qo, qc in l_terms]
            right = [(*stored(g * 2 + qg, o * 2 + qo), c * qc, 0)
                     for g, o, c in terms for qg, qo, qc in r_terms]
            dests = []
            for gi, gj, pc in placements:
                full = gi != gj
                for di, dj, dc, dtr in m_dests:
                    dests.append((gi * 2 + di, gj * 2 + dj, pc * dc, dtr))
                    if full and di != dj:
                        dests.append((gi * 2 + dj, gj * 2 + di, pc * dc,
                                      dtr ^ 1))
            _expand(levels - depth - 1, left, right, dests, "mm",
                    not rows, rows, table, (2, 2, 2), ops)

    node([(0, 0, 1)], 0, [(0, 0, 1)])

    # tri-packed output: strictly-upper leaf dests are redundant mirrors
    # of stored cells — drop them, merge duplicates per (cell, trans).
    pruned: List[LeafOp] = []
    for p in ops:
        kept = _merge_cells([d for d in p.dests if d[0] >= d[1]])
        assert kept, "leaf op lost every stored destination"
        pruned.append(LeafOp(p.kind, p.left, p.right,
                             tuple((di, dj, c, tr)
                                   for (di, dj, tr), c in kept)))
    return tuple(pruned)


@functools.lru_cache(maxsize=None)
def compile_program(kind: str, levels: int, variant: str = "strassen", *,
                    gram: str = "strassen",
                    trans_a: bool = False,
                    trans_b: bool = False) -> LeafProgram:
    """Compile ``kind`` at ``levels`` against the registered tables.

    ``variant`` names the multiplication algebra (may be rectangular
    for ``matmul``; ``symm`` needs a square right split, gram kinds a
    fully square <2, 2, 2> split).  ``gram`` names the gram algebra
    shaping the symmetric recursion — gram kinds only.  ``trans_a`` /
    ``trans_b`` apply to ``matmul`` only: the op list is identical
    (terms name stored blocks either way); only the operand specs
    change, and the executor folds the swap into its index maps.
    """
    if levels < 0:
        raise ValueError(f"levels must be >= 0, got {levels}")
    if kind not in PROGRAM_KINDS:
        raise ValueError(f"unknown program kind {kind!r} "
                         f"(want one of {PROGRAM_KINDS})")
    if (trans_a or trans_b) and kind != "matmul":
        raise ValueError(f"trans_a/trans_b only apply to matmul, not {kind!r}")
    if gram != "strassen" and kind not in ("ata", "aat", "rank_k"):
        raise ValueError(f"gram algebra selection only applies to gram "
                         f"kinds, not {kind!r}")
    table = get_algebra(variant)
    dims = algebra_dims(variant)

    if kind in ("ata", "aat", "rank_k"):
        if dims != (2, 2, 2):
            raise ValueError(
                f"gram kinds recurse over a square 2x2 split; algebra "
                f"{variant!r} is <{dims[0]},{dims[1]},{dims[2]}>")
        galg = get_gram_algebra(gram)
        ops = _compile_gram(levels, table, galg, rows=kind == "aat")
        if kind == "aat":
            return LeafProgram(
                kind, levels, variant, ops,
                left_spec=OperandSpec(source=0),
                right_spec=OperandSpec(source=0, transpose=True),
                out_spec=OutputSpec(packing="tri"),
                dims=dims, gram=gram)
        return LeafProgram(
            kind, levels, variant, ops,
            left_spec=OperandSpec(source=0, transpose=True),
            right_spec=OperandSpec(source=0),
            out_spec=OutputSpec(packing="tri", accumulate=kind == "rank_k"),
            dims=dims, gram=gram)

    if kind == "matmul":
        ops: List[LeafOp] = []
        _expand(levels, [(0, 0, 1, 0)], [(0, 0, 1, 0)], [(0, 0, 1, 0)], "mm",
                trans_a, trans_b, table, dims, ops)
        return LeafProgram(
            kind, levels, variant, tuple(ops),
            left_spec=OperandSpec(source=0, transpose=trans_a),
            right_spec=OperandSpec(source=1, transpose=trans_b),
            out_spec=OutputSpec(packing="dense"), dims=dims)

    # symm: a matmul flattening with the right terms normalized onto the
    # stored lower triangle — mirrored terms read transposed (trans = 1).
    # The packed operand is square, so the right split must have k == n.
    if dims[1] != dims[2]:
        raise ValueError(
            f"symm needs a square right split (k == n); algebra "
            f"{variant!r} is <{dims[0]},{dims[1]},{dims[2]}>")
    base = compile_program("matmul", levels, variant)
    ops = tuple(
        LeafOp("mm", p.left,
               tuple((r, c, s, 0) if r >= c else (c, r, s, 1)
                     for (r, c, s, _t) in p.right),
               p.dests)
        for p in base.ops)
    return LeafProgram(
        "symm", levels, variant, ops,
        left_spec=OperandSpec(source=0),
        right_spec=OperandSpec(source=1, layout="tri"),
        out_spec=OutputSpec(packing="dense"), dims=dims)


# ---------------------------------------------------------------------------
# Dense numpy interpreter — the parity oracle, independent of Pallas.
# ---------------------------------------------------------------------------

def _leaf(a: np.ndarray, r: int, c: int, grid) -> np.ndarray:
    mb, nb = a.shape[0] // grid[0], a.shape[1] // grid[1]
    return a[r * mb:(r + 1) * mb, c * nb:(c + 1) * nb]


def _gather_side(arr: np.ndarray, terms, grid, spec: OperandSpec,
                 diag_sym: bool = False) -> np.ndarray:
    """Signed sum of one side's stored leaves, mirrors/transposes applied."""
    out = None
    for r, c, s, trans in terms:
        if spec.layout == "tri":
            assert r >= c, "tri-layout term referenced the upper triangle"
            leaf = _leaf(arr, r, c, grid)
            if r == c:
                low = np.tril(leaf)
                # diag_sym: Sym = S + S^t, so the diagonal leaf doubles
                # symmetrically; otherwise rebuild the symmetric completion
                leaf = low + (low.T if diag_sym else np.tril(low, -1).T)
            if trans:
                leaf = leaf.T
        else:
            leaf = _leaf(arr, r, c, grid)
            if trans:
                leaf = leaf.T
        blk = s * leaf
        out = blk if out is None else out + blk
    if spec.layout != "tri" and spec.transpose:
        out = out.T
    return out


def interpret_program(prog: LeafProgram, a: np.ndarray,
                      b: Optional[np.ndarray] = None, *,
                      c0: Optional[np.ndarray] = None,
                      diag_sym: bool = False) -> np.ndarray:
    """Execute a program densely in float64 numpy.

    ``a`` (and ``b`` for two-input kinds) must be pre-padded so every
    stored axis divides by its per-axis leaf-grid count (``blocks_m`` x
    ``blocks_k`` for the stored left operand, swapped under the spec
    transpose).  For ``symm``, ``b`` is the dense (n, n) array whose
    strict upper triangle is provably never read (the packed-storage
    contract); ``diag_sym`` computes ``x @ (S + S^t)`` instead.  For
    ``rank_k``, ``c0`` is the (n, n) initial C (lower triangle; defaults
    to zero).

    Returns: tril(C) for tri-packed outputs, dense C otherwise.
    """
    af = np.asarray(a, np.float64)
    operands = {0: af}
    if prog.left_spec.source == 1 or prog.right_spec.source == 1:
        assert b is not None, f"{prog.kind} needs a second operand"
        operands[1] = np.asarray(b, np.float64)
        if prog.right_spec.layout == "tri":
            operands[1] = np.tril(operands[1])     # upper provably unread

    bm, bk, bn = prog.blocks_m, prog.blocks_k, prog.blocks_n
    lgrid = (bk, bm) if prog.left_spec.transpose else (bm, bk)
    rgrid = (bn, bk) if prog.right_spec.transpose else (bk, bn)
    for side, grid in (("left", lgrid), ("right", rgrid)):
        spec = getattr(prog, f"{side}_spec")
        shape = operands[spec.source].shape
        assert shape[0] % grid[0] == 0 and shape[1] % grid[1] == 0, \
            (side, shape, grid)

    # output geometry per kind
    m, n = af.shape
    if prog.kind in ("ata", "rank_k"):
        out_n = (n, n)
    elif prog.kind == "aat":
        out_n = (m, m)
    elif prog.kind == "symm":
        out_n = (m, operands[1].shape[1])
    else:
        la, lb = operands[0].shape, operands[1].shape
        out_n = ((la[1] if prog.left_spec.transpose else la[0]),
                 (lb[0] if prog.right_spec.transpose else lb[1]))
    out = np.zeros(out_n, np.float64)
    if c0 is not None:
        assert prog.out_spec.accumulate, \
            f"{prog.kind} output does not accumulate"
        out += np.tril(np.asarray(c0, np.float64))
    ogrid = prog.out_blocks
    mb, nb = out_n[0] // ogrid[0], out_n[1] // ogrid[1]

    for p in prog.ops:
        left = _gather_side(operands[prog.left_spec.source], p.left, lgrid,
                            prog.left_spec)
        right = _gather_side(operands[prog.right_spec.source], p.right, rgrid,
                             prog.right_spec, diag_sym=diag_sym)
        prod = left @ right
        for di, dj, s, tr in p.dests:
            blk = prod.T if tr else prod
            out[di * mb:(di + 1) * mb, dj * nb:(dj + 1) * nb] += s * blk
    if prog.out_spec.packing == "tri":
        out = np.tril(out)
    return out
