"""Leaf-program IR: one compilable representation for every fused variant.

PRs 1-4 grew three separately hand-specialized planner/executor stacks —
the forward ATA flattening, the symm (Gram-backward) flattening and the
trans_a/trans_b matmul paths.  Benson & Ballard ("A Framework for
Practical Parallel Fast Matrix Multiplication") make the observation this
module encodes: a fast-matmul variant is *data* — an algebra table of
(operand quadrants, output quadrants) coefficient rows — fed to one
generic executor.  Arrigoni & Massini's follow-up ("Efficiently
Parallelizable Strassen-Based Multiplication of a Matrix by its
Transpose", 2021) is then just one more recursion over the same tables:
``A A^t`` instead of ``A^t A``.

The IR has three layers:

* **Algebra tables** (:data:`ALGEBRAS`, :func:`register_algebra`) — the
  per-level expansion rules.  Each table is a tuple of rows
  ``(a_quads, b_quads, dest_quads)`` with entries ``(row, col, sign)``
  over the 2x2 quadrant grid.  strassen / winograd / classical ship
  registered; a new variant is one :func:`register_algebra` call away
  (DESIGN.md §12).

* **LeafProgram** (:func:`compile_program`) — a *kind* (``ata`` |
  ``aat`` | ``matmul`` | ``symm`` | ``rank_k``) recursively flattened
  against a table into leaf ops.  Every operand term is a uniform
  4-tuple ``(row, col, sign, trans)`` naming a **stored** leaf block of
  the operand plus a per-term transpose/mirror flag; every destination
  is ``(di, dj, sign)``.  Whole-operand properties (storage layout,
  operand-level transpose, which input the side reads) live on
  :class:`OperandSpec`; output packing and the accumulate flag live on
  :class:`OutputSpec`.  The executor in ``kernels/strassen_fused.py``
  binds a program to tile sizes and lowers it to scalar-prefetch tables
  for ONE generic ``pallas_call``.

* **Interpreter** (:func:`interpret_program`) — a dense numpy evaluation
  of a program, the parity oracle the Pallas executor (and the property
  suite) is checked against.

Kinds:

``ata``     C = tril(A^t A)       — paper Alg. 1 (column gram).
``aat``     C = tril(A A^t)       — Arrigoni-Massini 2021 (row gram):
            C11 = AAT(A11)+AAT(A12); C22 = AAT(A21)+AAT(A22);
            C21 = A21 A11^t + A22 A12^t (Strassen, right transposed).
``matmul``  C = op(A) @ op(B)     — level-capped Strassen; the
            ``trans_a``/``trans_b`` variants are the same op list with
            the OperandSpec transposes set (terms always name stored
            blocks, so the executor folds the swap into its index maps).
``symm``    D = X @ Sym           — Sym symmetric, stored as its lower
            triangle only; upper-triangle terms are mirrored onto the
            stored triangle with the per-term trans flag set.
``rank_k``  C += A^t A            — the ``ata`` program with the output
            accumulate flag: the executor seeds each output tile from
            the incoming packed stack instead of zero, so streamed Gram
            chunks never re-materialize C.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "ALGEBRAS", "register_algebra", "get_algebra", "registered_algebras",
    "OperandSpec", "OutputSpec", "LeafOp", "Contribution", "LeafProgram",
    "PROGRAM_KINDS", "compile_program", "interpret_program",
]

# A term is (row_block, col_block, sign, trans) over the 2^levels leaf
# grid of the STORED operand; trans = 1 means the leaf is read transposed
# (symm: the term was mirrored onto the stored lower triangle).
Term = Tuple[int, int, int, int]
# A destination is (dest_row_block, dest_col_block, sign).
Dest = Tuple[int, int, int]

PROGRAM_KINDS = ("ata", "aat", "matmul", "symm", "rank_k")


# ---------------------------------------------------------------------------
# Algebra-table registry (Benson-Ballard: variants are data, not code)
# ---------------------------------------------------------------------------

# Strassen's 7 products, matching strassen.py (incl. the M7 sign erratum
# fix recorded in DESIGN.md §9: second operand of M7 is B21 + B22).
_STRASSEN = (
    # M1 = (A11 + A22)(B11 + B22) -> C11 + C22
    (((0, 0, 1), (1, 1, 1)), ((0, 0, 1), (1, 1, 1)), ((0, 0, 1), (1, 1, 1))),
    # M2 = (A21 + A22) B11 -> C21 - C22
    (((1, 0, 1), (1, 1, 1)), ((0, 0, 1),), ((1, 0, 1), (1, 1, -1))),
    # M3 = A11 (B12 - B22) -> C12 + C22
    (((0, 0, 1),), ((0, 1, 1), (1, 1, -1)), ((0, 1, 1), (1, 1, 1))),
    # M4 = A22 (B21 - B11) -> C11 + C21
    (((1, 1, 1),), ((1, 0, 1), (0, 0, -1)), ((0, 0, 1), (1, 0, 1))),
    # M5 = (A11 + A12) B22 -> -C11 + C12
    (((0, 0, 1), (0, 1, 1)), ((1, 1, 1),), ((0, 0, -1), (0, 1, 1))),
    # M6 = (A21 - A11)(B11 + B12) -> C22
    (((1, 0, 1), (0, 0, -1)), ((0, 0, 1), (0, 1, 1)), ((1, 1, 1),)),
    # M7 = (A12 - A22)(B21 + B22) -> C11
    (((0, 1, 1), (1, 1, -1)), ((1, 0, 1), (1, 1, 1)), ((0, 0, 1),)),
)

# Winograd's variant (7 mults / 15 adds), destinations expanded from the
# u-term recombination in strassen.py.
_WINOGRAD = (
    # M1 = A11 B11
    (((0, 0, 1),), ((0, 0, 1),),
     ((0, 0, 1), (0, 1, 1), (1, 0, 1), (1, 1, 1))),
    # M2 = A12 B21
    (((0, 1, 1),), ((1, 0, 1),), ((0, 0, 1),)),
    # M3 = (A11 + A12 - A21 - A22) B22
    (((0, 0, 1), (0, 1, 1), (1, 0, -1), (1, 1, -1)), ((1, 1, 1),),
     ((0, 1, 1),)),
    # M4 = A22 (B11 - B12 - B21 + B22)
    (((1, 1, 1),), ((0, 0, 1), (0, 1, -1), (1, 0, -1), (1, 1, 1)),
     ((1, 0, -1),)),
    # M5 = (A21 + A22)(B12 - B11)
    (((1, 0, 1), (1, 1, 1)), ((0, 1, 1), (0, 0, -1)),
     ((0, 1, 1), (1, 1, 1))),
    # M6 = (A21 + A22 - A11)(B11 + B22 - B12)
    (((1, 0, 1), (1, 1, 1), (0, 0, -1)), ((0, 0, 1), (1, 1, 1), (0, 1, -1)),
     ((0, 1, 1), (1, 0, 1), (1, 1, 1))),
    # M7 = (A11 - A21)(B22 - B12)
    (((0, 0, 1), (1, 0, -1)), ((1, 1, 1), (0, 1, -1)),
     ((1, 0, 1), (1, 1, 1))),
)

# Classical 2x2 block multiply in the same representation (8 products).
_CLASSICAL = tuple(
    (((i, k, 1),), ((k, j, 1),), ((i, j, 1),))
    for i in (0, 1) for j in (0, 1) for k in (0, 1)
)

#: name -> algebra table.  Mutated only through :func:`register_algebra`.
ALGEBRAS: Dict[str, tuple] = {}

#: callbacks run whenever the registry changes — downstream lru caches
#: keyed on the variant name (the executor's scalar-prefetch tables in
#: ``kernels/strassen_fused.py``) register here so a re-registration
#: cannot leave a stale compiled table behind.
_INVALIDATION_HOOKS: list = []


def on_algebra_change(fn) -> None:
    """Register ``fn()`` to run whenever an algebra table is
    (re)registered.  Used by variant-keyed caches downstream."""
    _INVALIDATION_HOOKS.append(fn)


def register_algebra(name: str, table, *, overwrite: bool = False) -> None:
    """Register a 2x2-recursion algebra table under ``name``.

    ``table`` is a tuple of rows ``(a_quads, b_quads, dest_quads)``;
    each quad list holds ``(row, col, sign)`` entries over {0, 1}^2 with
    sign in {+1, -1}.  Registration validates the format (not the
    algebraic identity — :func:`interpret_program` against a dense
    oracle is the correctness check; see tests/test_leaf_ir.py).
    """
    if not overwrite and name in ALGEBRAS:
        raise ValueError(f"algebra {name!r} already registered")
    for row in table:
        if len(row) != 3:
            raise ValueError(f"algebra row must be (a, b, dest) triple: "
                             f"{row!r}")
        for quads in row:
            for q in quads:
                r, c, s = q
                if r not in (0, 1) or c not in (0, 1) or s not in (1, -1):
                    raise ValueError(f"bad quadrant entry {q!r} in {name!r}")
    ALGEBRAS[name] = tuple(tuple(map(tuple, (a, b, d))) for a, b, d in table)
    # re-registration changes what compile_program(levels, name) means —
    # and every downstream cache keyed on the variant name
    if "compile_program" in globals():
        compile_program.cache_clear()
    for fn in _INVALIDATION_HOOKS:
        fn()


def get_algebra(name: str) -> tuple:
    try:
        return ALGEBRAS[name]
    except KeyError:
        raise ValueError(
            f"unknown algebra {name!r}; registered: "
            f"{sorted(ALGEBRAS)}") from None


def registered_algebras() -> Tuple[str, ...]:
    return tuple(sorted(ALGEBRAS))


register_algebra("strassen", _STRASSEN)
register_algebra("winograd", _WINOGRAD)
register_algebra("classical", _CLASSICAL)


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OperandSpec:
    """Whole-side properties of one program operand.

    source:    which executor input the side reads (0 = first array,
               1 = second; ``ata``/``aat``/``rank_k`` read the same
               array on both sides).
    layout:    "dense" (a plain (rows, cols) array over the leaf grid)
               or "tri" (the packed lower-triangular tile stack of
               ``kernels/syrk.py`` — terms then carry the mirror flag).
    transpose: the side is *used* transposed: the executor swaps the
               roles of the stored axes in its index maps and flips the
               gathered sum tile-wise in VMEM.  Never set together with
               layout="tri" (tri mirroring is per-term).
    """
    source: int = 0
    layout: str = "dense"
    transpose: bool = False


@dataclass(frozen=True)
class OutputSpec:
    """packing: "tri" = packed lower-triangular tile stack (di >= dj
    always), "dense" = full block grid.  accumulate: seed each output
    tile from an incoming stack (C += ...) instead of zero."""
    packing: str = "dense"
    accumulate: bool = False


@dataclass(frozen=True)
class LeafOp:
    """One leaf product: (signed sum of stored blocks) x (signed sum)."""
    kind: str                 # "syrk" (gram diagonal leaf) | "mm"
    left: Tuple[Term, ...]
    right: Tuple[Term, ...]
    dests: Tuple[Dest, ...]


@dataclass(frozen=True)
class Contribution:
    """One (leaf op, destination) pair — the unit the executor runs."""
    di: int
    dj: int
    sign: int
    left: Tuple[Term, ...]
    right: Tuple[Term, ...]
    kind: str


@dataclass(frozen=True)
class LeafProgram:
    """A fully flattened schedule over a ``2^levels`` leaf-block grid.

    This is the compat superset of the old ``core.schedule.Plan``:
    ``products`` / ``blocks`` / ``max_terms`` / ``contributions`` /
    ``by_dest`` / ``max_contributions`` / ``mult_count`` keep their
    PR-1 meanings, and the new ``left_spec`` / ``right_spec`` /
    ``out_spec`` fields carry what used to be implicit in the kind.
    """
    kind: str
    levels: int
    variant: str
    ops: Tuple[LeafOp, ...]
    left_spec: OperandSpec
    right_spec: OperandSpec
    out_spec: OutputSpec

    # -- compat surface (Plan) ---------------------------------------------
    @property
    def products(self) -> Tuple[LeafOp, ...]:
        return self.ops

    @property
    def blocks(self) -> int:
        """Leaf blocks per matrix dimension."""
        return 1 << self.levels

    @property
    def max_terms(self) -> int:
        return max(max(len(p.left), len(p.right)) for p in self.ops)

    @functools.lru_cache(maxsize=None)
    def contributions(self) -> Tuple[Contribution, ...]:
        """(op, destination) pairs, sorted by destination block."""
        out = [
            Contribution(di, dj, s, p.left, p.right, p.kind)
            for p in self.ops for (di, dj, s) in p.dests
        ]
        out.sort(key=lambda c: (c.di, c.dj))
        return tuple(out)

    @functools.lru_cache(maxsize=None)
    def by_dest(self) -> Dict[Tuple[int, int], Tuple[Contribution, ...]]:
        grouped: Dict[Tuple[int, int], list] = {}
        for c in self.contributions():
            grouped.setdefault((c.di, c.dj), []).append(c)
        return {k: tuple(v) for k, v in grouped.items()}

    @property
    def max_contributions(self) -> int:
        return max(len(v) for v in self.by_dest().values())

    def n_dests(self) -> int:
        """Distinct leaf destinations of the output packing."""
        B = self.blocks
        return B * (B + 1) // 2 if self.out_spec.packing == "tri" else B * B

    def dest_index(self, di: int, dj: int) -> int:
        if self.out_spec.packing == "tri":
            return di * (di + 1) // 2 + dj
        return di * self.blocks + dj

    def mult_count(self, mb: int, nb: int, kb: Optional[int] = None) -> int:
        """Scalar multiplications the program performs with the given
        leaf shapes.  Gram kinds (``ata``/``rank_k``: A leaves (mb, nb);
        ``aat``: (mb, nb) with the roles of the grids swapped): SYRK
        leaves compute only the lower triangle — the paper's n(n+1)/2
        saving.  ``matmul``: leaves (mb, kb) x (kb, nb).  ``symm``: X
        leaves (mb, nb) against square (nb, nb) leaves of the packed
        operand.  Matches the ``cost_model`` closed forms evaluated with
        ``leaf=0`` at the padded shape (tests/test_properties.py).
        """
        total = 0
        for p in self.ops:
            if p.kind == "syrk":
                if self.kind == "aat":
                    total += nb * mb * (mb + 1) // 2
                else:
                    total += mb * nb * (nb + 1) // 2
            elif self.kind in ("ata", "rank_k"):
                total += nb * mb * nb          # (nb, mb) @ (mb, nb)
            elif self.kind == "aat":
                total += mb * nb * mb          # (mb, nb) @ (nb, mb)
            elif self.kind == "symm":
                total += mb * nb * nb          # (mb, nb) @ (nb, nb)
            else:
                total += mb * (kb if kb is not None else nb) * nb
        return total


# ---------------------------------------------------------------------------
# The compiler: kind x levels x algebra -> LeafProgram
# ---------------------------------------------------------------------------

def _expand(level: int, left, right, dests, kind, transpose_left,
            transpose_right, table, out: List[LeafOp]):
    """Recursively expand a block product ``level`` more times.

    ``transpose_left`` / ``transpose_right``: that side is conceptually
    ``X^t`` while its terms name stored blocks of ``X`` — quadrant
    (qi, qj) of ``X^t`` is stored block (qj, qi), so quadrant bits
    append swapped on that side.
    """
    if level <= 0:
        out.append(LeafOp(kind, tuple(left), tuple(right), tuple(dests)))
        return
    for a_quads, b_quads, d_quads in table:
        nl = []
        for qi, qj, s in a_quads:
            rb, cb = (qj, qi) if transpose_left else (qi, qj)
            nl.extend((r * 2 + rb, c * 2 + cb, s0 * s, 0)
                      for r, c, s0, _t in left)
        nr = []
        for qi, qj, s in b_quads:
            rb, cb = (qj, qi) if transpose_right else (qi, qj)
            nr.extend((r * 2 + rb, c * 2 + cb, s0 * s, 0)
                      for r, c, s0, _t in right)
        nd = []
        for ci, cj, s in d_quads:
            nd.extend((di * 2 + ci, dj * 2 + cj, s0 * s)
                      for di, dj, s0 in dests)
        _expand(level - 1, nl, nr, nd, kind, transpose_left,
                transpose_right, table, out)


def _compile_gram(levels: int, table, *, rows: bool) -> Tuple[LeafOp, ...]:
    """Flatten the gram recursion (Alg. 1, or its 2021 row-space dual).

    ``rows=False`` (ATA, C = A^t A over the column grid):
      C11 = ATA(A11) + ATA(A21);  C22 = ATA(A12) + ATA(A22)
      C21 = HASA(A12^t, A11) + HASA(A22^t, A21)
    SYRK leaves land on diagonal destinations of the *column* grid, HASA
    leaves strictly below — the left side is conceptually transposed.

    ``rows=True`` (AAT, C = A A^t over the row grid — Arrigoni-Massini):
      C11 = AAT(A11) + AAT(A12);  C22 = AAT(A21) + AAT(A22)
      C21 = HASA(A21, A11^t) + HASA(A22, A12^t)
    SYRK leaves land on diagonal destinations of the *row* grid; the
    right side is conceptually transposed.
    """
    ops: List[LeafOp] = []

    def node(r: int, c: int, depth: int):
        if depth == levels:
            d = r if rows else c
            ops.append(LeafOp("syrk", ((r, c, 1, 0),), ((r, c, 1, 0),),
                              ((d, d, 1),)))
            return
        for rb in (0, 1):
            for cb in (0, 1):
                node(r * 2 + rb, c * 2 + cb, depth + 1)
        # the off-diagonal C21 of this node, expanded the remaining
        # levels with the algebra table; terms name STORED blocks of A —
        # the transpose flags handle the quadrant mirroring, the
        # executor transposes tiles in VMEM.
        for b in (0, 1):
            if rows:
                left = [(r * 2 + 1, c * 2 + b, 1, 0)]
                right = [(r * 2 + 0, c * 2 + b, 1, 0)]
                dest = [(r * 2 + 1, r * 2 + 0, 1)]
            else:
                left = [(r * 2 + b, c * 2 + 1, 1, 0)]
                right = [(r * 2 + b, c * 2 + 0, 1, 0)]
                dest = [(c * 2 + 1, c * 2 + 0, 1)]
            _expand(levels - depth - 1, left, right, dest, "mm",
                    not rows, rows, table, ops)

    node(0, 0, 0)
    return tuple(ops)


@functools.lru_cache(maxsize=None)
def compile_program(kind: str, levels: int, variant: str = "strassen", *,
                    trans_a: bool = False,
                    trans_b: bool = False) -> LeafProgram:
    """Compile ``kind`` at ``levels`` against a registered algebra table.

    ``trans_a`` / ``trans_b`` apply to ``matmul`` only: the op list is
    identical (terms name stored blocks either way); only the operand
    specs change, and the executor folds the swap into its index maps.
    """
    if levels < 0:
        raise ValueError(f"levels must be >= 0, got {levels}")
    if kind not in PROGRAM_KINDS:
        raise ValueError(f"unknown program kind {kind!r} "
                         f"(want one of {PROGRAM_KINDS})")
    if (trans_a or trans_b) and kind != "matmul":
        raise ValueError(f"trans_a/trans_b only apply to matmul, not {kind!r}")
    table = get_algebra(variant)

    if kind in ("ata", "rank_k"):
        ops = _compile_gram(levels, table, rows=False)
        return LeafProgram(
            kind, levels, variant, ops,
            left_spec=OperandSpec(source=0, transpose=True),
            right_spec=OperandSpec(source=0),
            out_spec=OutputSpec(packing="tri", accumulate=kind == "rank_k"))

    if kind == "aat":
        ops = _compile_gram(levels, table, rows=True)
        return LeafProgram(
            kind, levels, variant, ops,
            left_spec=OperandSpec(source=0),
            right_spec=OperandSpec(source=0, transpose=True),
            out_spec=OutputSpec(packing="tri"))

    if kind == "matmul":
        ops: List[LeafOp] = []
        _expand(levels, [(0, 0, 1, 0)], [(0, 0, 1, 0)], [(0, 0, 1)], "mm",
                trans_a, trans_b, table, ops)
        return LeafProgram(
            kind, levels, variant, tuple(ops),
            left_spec=OperandSpec(source=0, transpose=trans_a),
            right_spec=OperandSpec(source=1, transpose=trans_b),
            out_spec=OutputSpec(packing="dense"))

    # symm: a matmul flattening with the right terms normalized onto the
    # stored lower triangle — mirrored terms read transposed (trans = 1).
    base = compile_program("matmul", levels, variant)
    ops = tuple(
        LeafOp("mm", p.left,
               tuple((r, c, s, 0) if r >= c else (c, r, s, 1)
                     for (r, c, s, _t) in p.right),
               p.dests)
        for p in base.ops)
    return LeafProgram(
        "symm", levels, variant, ops,
        left_spec=OperandSpec(source=0),
        right_spec=OperandSpec(source=1, layout="tri"),
        out_spec=OutputSpec(packing="dense"))


# ---------------------------------------------------------------------------
# Dense numpy interpreter — the parity oracle, independent of Pallas.
# ---------------------------------------------------------------------------

def _leaf(a: np.ndarray, r: int, c: int, blocks: int) -> np.ndarray:
    mb, nb = a.shape[0] // blocks, a.shape[1] // blocks
    return a[r * mb:(r + 1) * mb, c * nb:(c + 1) * nb]


def _gather_side(arr: np.ndarray, terms, blocks: int, spec: OperandSpec,
                 diag_sym: bool = False) -> np.ndarray:
    """Signed sum of one side's stored leaves, mirrors/transposes applied."""
    out = None
    for r, c, s, trans in terms:
        if spec.layout == "tri":
            assert r >= c, "tri-layout term referenced the upper triangle"
            leaf = _leaf(arr, r, c, blocks)
            if r == c:
                low = np.tril(leaf)
                # diag_sym: Sym = S + S^t, so the diagonal leaf doubles
                # symmetrically; otherwise rebuild the symmetric completion
                leaf = low + (low.T if diag_sym else np.tril(low, -1).T)
            if trans:
                leaf = leaf.T
        else:
            leaf = _leaf(arr, r, c, blocks)
            if trans:
                leaf = leaf.T
        blk = s * leaf
        out = blk if out is None else out + blk
    if spec.layout != "tri" and spec.transpose:
        out = out.T
    return out


def interpret_program(prog: LeafProgram, a: np.ndarray,
                      b: Optional[np.ndarray] = None, *,
                      c0: Optional[np.ndarray] = None,
                      diag_sym: bool = False) -> np.ndarray:
    """Execute a program densely in float64 numpy.

    ``a`` (and ``b`` for two-input kinds) must be pre-padded to
    ``prog.blocks`` multiples in both dims.  For ``symm``, ``b`` is the
    dense (n, n) array whose strict upper triangle is provably never
    read (the packed-storage contract); ``diag_sym`` computes
    ``x @ (S + S^t)`` instead.  For ``rank_k``, ``c0`` is the (n, n)
    initial C (lower triangle; defaults to zero).

    Returns: tril(C) for tri-packed outputs, dense C otherwise.
    """
    B = prog.blocks
    af = np.asarray(a, np.float64)
    m, n = af.shape
    assert m % B == 0 and n % B == 0, (af.shape, B)
    operands = {0: af}
    if prog.left_spec.source == 1 or prog.right_spec.source == 1:
        assert b is not None, f"{prog.kind} needs a second operand"
        operands[1] = np.asarray(b, np.float64)
        if prog.right_spec.layout == "tri":
            operands[1] = np.tril(operands[1])     # upper provably unread

    # output geometry per kind
    if prog.kind in ("ata", "rank_k"):
        out_n = (n, n)
    elif prog.kind == "aat":
        out_n = (m, m)
    elif prog.kind == "symm":
        out_n = (m, operands[1].shape[1])
    else:
        la, lb = operands[0].shape, operands[1].shape
        out_n = ((la[1] if prog.left_spec.transpose else la[0]),
                 (lb[0] if prog.right_spec.transpose else lb[1]))
    out = np.zeros(out_n, np.float64)
    if c0 is not None:
        assert prog.out_spec.accumulate, \
            f"{prog.kind} output does not accumulate"
        out += np.tril(np.asarray(c0, np.float64))
    mb, nb = out_n[0] // B, out_n[1] // B

    for p in prog.ops:
        left = _gather_side(operands[prog.left_spec.source], p.left, B,
                            prog.left_spec)
        right = _gather_side(operands[prog.right_spec.source], p.right, B,
                             prog.right_spec, diag_sym=diag_sym)
        prod = left @ right
        for di, dj, s in p.dests:
            out[di * mb:(di + 1) * mb, dj * nb:(dj + 1) * nb] += s * prod
    if prog.out_spec.packing == "tri":
        out = np.tril(out)
    return out
