"""HASA: Strassen's algorithm generalized to rectangular / odd-size matrices.

Faithful to the paper's use of D'Alberto & Nicolau's generalized Strassen
("HASA") as the subroutine for the off-diagonal block C21 = A12^t A11 +
A22^t A21 of the ATA recursion.

TPU adaptation (see DESIGN.md §2): the recursion is unrolled at *trace* time
(Python recursion over static shapes), capped at ``levels`` to bound jaxpr
growth; below the cap we fall back to a base matmul that is either
``jnp.dot`` (XLA) or the Pallas MXU kernel. Odd dimensions are handled by
zero-padding to even (equivalent to the paper's peeling, but keeps every
quadrant MXU-shaped), and the padding is sliced away on the way out.

Accumulation dtype is fp32 even for bf16 inputs — Strassen's recombination
loses ~1 bit/level, so we never accumulate in bf16.  For the same reason
results default to the promoted accumulation dtype (``out_dtype=``
downcasts explicitly when the caller wants the input dtype back).

``mode="fused"`` executes through the flattened leaf-task schedule
(``core/schedule.py`` + ``kernels/strassen_fused.py``) instead of this
recursion — see DESIGN.md §4 and the docstring in ``ata.py``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

# Base-case threshold: Strassen recursion stops when any dim is <= this.
# Paper uses 32 (CPU cache line / load-store cost balance). On TPU the MXU is
# a 128x128 systolic array, so sub-128 tiles waste the unit: we stop at 256.
DEFAULT_LEAF = 256
DEFAULT_LEVELS = 2

# Cap for levels="auto".  Each Strassen level saves 12.5% multiplications
# but costs one more bit of accumulated error, larger operand-sum fan-in
# (2^levels gathered tiles per operand in the fused kernel) and
# exponentially larger schedules/jaxprs; past ~3 levels the recombination
# overhead dominates on MXU-class hardware (paper §6 uses 1-2 parallel
# levels for the same reason).
AUTO_MAX_LEVELS = 3


def resolve_mode(mode: str, *leaf_hooks) -> str:
    """Resolve mode="auto" -> "fused" | "reference".

    Fused is the default on TPU; custom leaf hooks (base_syrk/base_matmul)
    force the reference recursion because the flattened schedule has no
    per-leaf call-out.  Off-TPU the reference recursion is both faster
    (XLA-compiled vs interpreted Pallas) and differentiable, so it stays
    the default there; tests exercise the fused path with interpret=True
    explicitly.
    """
    if mode == "auto":
        if any(h is not None for h in leaf_hooks):
            return "reference"
        return "fused" if jax.default_backend() == "tpu" else "reference"
    if mode not in ("fused", "reference"):
        raise ValueError(f"unknown mode {mode!r} "
                         "(want 'auto' | 'fused' | 'reference')")
    if mode == "fused" and any(h is not None for h in leaf_hooks):
        raise ValueError(
            "mode='fused' cannot honor base_syrk/base_matmul leaf hooks "
            "(the flattened schedule has no per-leaf call-out) — use "
            "mode='reference' or drop the hooks")
    return mode


def _default_base_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Classical base-case matmul with >=fp32 accumulation."""
    acc = jnp.promote_types(jnp.promote_types(a.dtype, b.dtype), jnp.float32)
    return jnp.dot(a, b, preferred_element_type=acc)


def _pad_to_even(x: jax.Array) -> jax.Array:
    m, n = x.shape
    pm, pn = m % 2, n % 2
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _quadrants(x: jax.Array):
    m, n = x.shape
    m2, n2 = m // 2, n // 2
    return (x[:m2, :n2], x[:m2, n2:], x[m2:, :n2], x[m2:, n2:])


def strassen_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    levels: Union[int, str] = DEFAULT_LEVELS,
    leaf: int = DEFAULT_LEAF,
    variant: str = "strassen",
    base_matmul: Optional[Callable] = None,
    mode: str = "auto",
    bwd: str = "fused",
    trans_a: bool = False,
    trans_b: bool = False,
    out_dtype=None,
    block: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Compute ``op(a) @ op(b)`` via (level-capped) Strassen recursion,
    ``op`` = transpose where the flag is set.

    Args:
      a: (m, k) array — or (k, m) with ``trans_a``.
      b: (k, n) array — or (n, k) with ``trans_b``.
      trans_a, trans_b: use an operand transposed.  The fused path folds
        the transpose into the executor's index maps (no transposed HBM
        copy — this is how ``core.distributed``'s ``A_loc^t A_perm``
        ring block tasks run); the reference recursion materializes
        ``.T`` (the oracle).
      levels: max recursion depth (0 => classical), or ``"auto"`` to
        recurse until a dim hits ``leaf`` (capped at AUTO_MAX_LEVELS).
      leaf: stop recursing when min(m, k, n) <= leaf (reference mode; also
        sets the "auto" depth).
      variant: "strassen" (7 mults / 18 adds, as in the paper),
               "winograd" (7 mults / 15 adds, beyond-paper option) or
               "classical".
      base_matmul: leaf matmul; defaults to jnp.dot w/ fp32 accumulation.
        Forces reference mode under ``mode="auto"``.
      mode: "auto" | "fused" | "reference" — fused executes the flattened
        schedule in one Pallas kernel (no per-level HBM temporaries).
      bwd: fused-path VJP engine — "fused" (default: both VJP products
        through the schedule kernel, transposes folded into index maps)
        or "dense" (classical jnp.dot VJP).  Reference mode ignores it.
      out_dtype: result dtype; defaults to the promoted *accumulation*
        dtype (fp32 for bf16/fp32 inputs) rather than downcasting.
      block: Pallas tile edge for the fused path (bm = bk = bn = block);
        ``None`` consults the gram autotune cache (256 when untuned).
      interpret: Pallas interpret override for the fused path.

    Returns (m, n) array in ``out_dtype``.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"bad shapes for matmul: {a.shape} x {b.shape}")
    m, k_a = a.shape[::-1] if trans_a else a.shape
    k_b, n = b.shape[::-1] if trans_b else b.shape
    if k_a != k_b:
        raise ValueError(
            f"bad shapes for matmul: {a.shape} x {b.shape} "
            f"(trans_a={trans_a}, trans_b={trans_b})")
    if levels == "auto":
        levels = min(strassen_levels_for(m, k_a, n, leaf), AUTO_MAX_LEVELS)
    out_dtype = (jnp.promote_types(jnp.promote_types(a.dtype, b.dtype),
                                   jnp.float32)
                 if out_dtype is None else jnp.dtype(out_dtype))
    mode = resolve_mode(mode, base_matmul)
    if mode == "fused":
        from ..kernels.ops import matmul_fused
        return matmul_fused(a, b, levels=levels, variant=variant, bm=block,
                            bk=block, bn=block, trans_a=trans_a,
                            trans_b=trans_b, out_dtype=out_dtype,
                            interpret=interpret, bwd=bwd)
    base = base_matmul or _default_base_matmul
    # reference oracle: materialize the transposes (the fused executor's
    # index-map folding is exactly what removes these copies)
    res = _strassen_rec(a.T if trans_a else a, b.T if trans_b else b,
                        levels, leaf, variant, base)
    return res.astype(out_dtype)


def _strassen_rec(a, b, levels, leaf, variant, base):
    m, k = a.shape
    _, n = b.shape
    if variant == "classical" or levels <= 0 or min(m, k, n) <= leaf:
        return base(a, b)

    # Pad odd dims to even so quadrants are well-formed (HASA handles
    # arbitrary sizes; zero-padding is the TPU-friendly equivalent of
    # peeling and is exact).
    ap, bp = _pad_to_even(a), _pad_to_even(b)
    mp, kp = ap.shape
    _, np_ = bp.shape

    a11, a12, a21, a22 = _quadrants(ap)
    b11, b12, b21, b22 = _quadrants(bp)

    rec = functools.partial(
        _strassen_rec, levels=levels - 1, leaf=leaf, variant=variant, base=base
    )

    if variant == "strassen":
        # The 7 products as distributed to P_ids0..P_ids6 in the paper's
        # HASA-P (§4.3.2). NOTE: the paper's listing types M7's second
        # operand as (B21 - B22); Strassen's identity requires (B21 + B22)
        # — verified numerically, recorded in DESIGN.md §9.
        m1 = rec(a11 + a22, b11 + b22)
        m2 = rec(a21 + a22, b11)
        m3 = rec(a11, b12 - b22)
        m4 = rec(a22, b21 - b11)
        m5 = rec(a11 + a12, b22)
        m6 = rec(a21 - a11, b11 + b12)
        m7 = rec(a12 - a22, b21 + b22)
        c11 = m1 + m4 - m5 + m7
        c12 = m3 + m5
        c21 = m2 + m4
        c22 = m1 - m2 + m3 + m6
    elif variant == "winograd":
        # Winograd's variant: 7 mults, 15 adds (beyond-paper constant-factor
        # improvement mentioned in §1 of the paper).
        s1 = a21 + a22
        s2 = s1 - a11
        s3 = a11 - a21
        s4 = a12 - s2
        t1 = b12 - b11
        t2 = b22 - t1
        t3 = b22 - b12
        t4 = t2 - b21
        m1 = rec(a11, b11)
        m2 = rec(a12, b21)
        m3 = rec(s4, b22)
        m4 = rec(a22, t4)
        m5 = rec(s1, t1)
        m6 = rec(s2, t2)
        m7 = rec(s3, t3)
        u1 = m1 + m6
        u2 = u1 + m7
        u3 = u1 + m5
        c11 = m1 + m2
        c12 = u3 + m3
        c21 = u2 - m4
        c22 = u2 + m5
    else:
        raise ValueError(f"unknown variant {variant!r}")

    c = jnp.concatenate(
        [jnp.concatenate([c11, c12], axis=1), jnp.concatenate([c21, c22], axis=1)],
        axis=0,
    )
    return c[:m, :n]  # strip padding


def strassen_levels_for(m: int, k: int, n: int, leaf: int = DEFAULT_LEAF) -> int:
    """Natural number of Strassen levels for a problem (cache-oblivious
    analogue: recurse until the leaf threshold)."""
    leaf = max(leaf, 1)        # (1+1)//2 == 1: leaf=0 would never terminate
    lv = 0
    while min(m, k, n) > leaf:
        m, k, n = (m + 1) // 2, (k + 1) // 2, (n + 1) // 2
        lv += 1
    return lv
