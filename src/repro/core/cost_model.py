"""Analytic cost model from the paper (§3.1, §4.1, §5, §6).

Used by the benchmark harness to replicate Figures 5-8 (exec time, speedup,
efficiency, Karp-Flatt) and by EXPERIMENTS.md to validate the complexity
claim (2/7) n^{log2 7}.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

LOG2_7 = math.log2(7.0)


# ---------------------------------------------------------------------------
# §3.1 — operation counts
# ---------------------------------------------------------------------------

def strassen_mults(n: float) -> float:
    """Multiplications of Strassen's algorithm, O(n^{log2 7})."""
    return n ** LOG2_7


def ata_mults_bound(n: float) -> float:
    """Paper's upper bound on ATA multiplications: (2/7) n^{log2 7}."""
    return (2.0 / 7.0) * n ** LOG2_7


def classical_ata_mults(n: float, m: float | None = None) -> float:
    """Conventional A^tA products: n(n+1)/2 inner products of length m
    (paper quotes n^2(n+1)/2 for square)."""
    m = n if m is None else m
    return m * n * (n + 1) / 2.0


def classical_matmul_mults(n: float) -> float:
    return n ** 3


def ata_mults_exact(m: int, n: int, leaf: int = 32, levels: int | None = None,
                    _memo=None) -> int:
    """Exact multiplication count of Algorithm 1 with a given leaf size,
    by direct evaluation of the recursion (classical leaf: m*n^2 products
    for the full leaf gram — we count the tril-only leaf: m*n*(n+1)/2)."""
    if _memo is None:
        _memo = {}
    key = (m, n, levels)
    if key in _memo:
        return _memo[key]
    if (levels is not None and levels <= 0) or m <= leaf or n <= leaf:
        res = m * n * (n + 1) // 2
    else:
        m1, m2 = (m + 1) // 2, m // 2
        n1, n2 = (n + 1) // 2, n // 2
        lv = None if levels is None else levels - 1
        res = (
            ata_mults_exact(m1, n1, leaf, lv, _memo)
            + ata_mults_exact(m2, n1, leaf, lv, _memo)
            + ata_mults_exact(m1, n2, leaf, lv, _memo)
            + ata_mults_exact(m2, n2, leaf, lv, _memo)
            + strassen_mults_exact(n2, m1, n1, leaf, lv, _memo)
            + strassen_mults_exact(n2, m2, n1, leaf, lv, _memo)
        )
    _memo[key] = res
    return res


# ---------------------------------------------------------------------------
# Leaf-IR closed forms (core/leaf_ir.py): leaf-op and operand-term counts
# of every compiled program kind, as functions of the algebra table's two
# scalars — products per level t and max operand fan-in q — and, for gram
# kinds, the gram algebra's recursion shape (n_sym recursive Grams +
# n_mm general products per level).  The property suite
# (tests/test_leaf_ir.py) pins compile_program against these for every
# registered algebra x gram algebra x kind x levels 0-3.
# ---------------------------------------------------------------------------

def _algebra_scalars(variant: str) -> tuple[int, int]:
    """(products per level, max operand quadrant fan-in) of a registered
    algebra table — derived from the table itself so user-registered
    algebras are covered, but pure table inspection (no compilation)."""
    from .leaf_ir import get_algebra
    table = get_algebra(variant)
    t = len(table)
    q = max(max(len(a), len(b)) for a, b, _d in table)
    return t, q


def _gram_scalars(gram: str) -> tuple[int, int, int, int]:
    """(n_sym, n_mm, sym term fan-in, mm term fan-in) of a registered
    gram algebra — pure table inspection, like :func:`_algebra_scalars`."""
    from .leaf_ir import get_gram_algebra
    galg = get_gram_algebra(gram)
    n_sym, n_mm = len(galg["sym"]), len(galg["mm"])
    f_sym = max(len(terms) for terms, _d in galg["sym"])
    f_mm = max(max(len(lt), len(rt)) for lt, rt, _d in galg["mm"])
    return n_sym, n_mm, f_sym, f_mm


def ir_leaf_count(kind: str, levels: int, variant: str = "strassen",
                  gram: str = "strassen") -> int:
    """Leaf ops of ``compile_program(kind, levels, variant, gram=gram)``.

    matmul/symm: t^levels (one table row choice per level).
    Gram kinds (ata/aat/rank_k): G(l) = n_sym G(l-1) + n_mm t^(l-1),
    G(0) = 1 — the gram algebra's recursive Gram calls plus its general
    products expanded with the table (strassen-gram: 4 + 2 t^(l-1);
    dps: 2 + 3 t^(l-1), strictly fewer at every level).
    """
    t, _q = _algebra_scalars(variant)
    if kind in ("matmul", "symm"):
        return t ** levels
    if kind in ("ata", "aat", "rank_k"):
        n_sym, n_mm, _fs, _fm = _gram_scalars(gram)
        g = 1
        for lv in range(1, levels + 1):
            g = n_sym * g + n_mm * t ** (lv - 1)
        return g
    raise ValueError(f"unknown IR kind {kind!r}")


def ir_max_terms(kind: str, levels: int, variant: str = "strassen",
                 gram: str = "strassen") -> int:
    """Max operand-term fan-in of a compiled program: q^levels for
    matmul/symm.  Gram kinds: a depth-d sym chain compounds its term
    fan-in f_sym d times; an mm product spawned at depth d starts at
    f_sym^d * f_mm terms and expands the remaining levels-1-d splits at
    q per level (SYRK leaves stay at f_sym^levels).  The classic
    strassen-gram entry (f_sym = f_mm = 1) reduces to q^(levels-1)."""
    _t, q = _algebra_scalars(variant)
    if kind in ("matmul", "symm"):
        return q ** levels
    if kind in ("ata", "aat", "rank_k"):
        n_sym, _n_mm, f_sym, f_mm = _gram_scalars(gram)
        best = f_sym ** levels
        for d in range(levels):
            best = max(best, f_sym ** d * f_mm * q ** (levels - 1 - d))
        return best
    raise ValueError(f"unknown IR kind {kind!r}")


def gram_serve_work(m: int, n: int, *, gram_of: str = "cols",
                    leaf: int = 32, levels: int | None = None) -> int:
    """Admission-control work units for one serving-bucket Gram request:
    the exact leaf-product count of the recursion the engine will run
    (column gram, or the row gram for ``gram_of="rows"``).
    ``gram.engine``'s CoDel-style shedder and WFQ scheduler price queued
    work in these machine-independent units and convert to seconds with
    a measured seconds-per-unit EWMA."""
    if gram_of == "rows":
        return aat_mults_exact(m, n, leaf=leaf, levels=levels)
    return ata_mults_exact(m, n, leaf=leaf, levels=levels)


def aat_mults_exact(m: int, n: int, leaf: int = 32,
                    levels: int | None = None) -> int:
    """Exact multiplication count of the row-gram recursion (Arrigoni-
    Massini 2021, C = A A^t): AAT(A) = ATA(A^t) exactly, so the count is
    the column-gram count with the dimensions swapped."""
    return ata_mults_exact(n, m, leaf, levels)


def symm_leaf_count(levels: int, variant: str = "strassen") -> int:
    """Leaf products of a flattened ``X @ Sym`` schedule
    (``core.schedule.plan_symm``): one table-row choice per level, so
    t^levels with t the registered table's product count (7 for the
    fast square variants, 8 classical, 11 for <3,2,2> bb322, ...) —
    derived from the table itself, so user-registered algebras count
    correctly instead of being silently priced as Strassen."""
    t, _q = _algebra_scalars(variant)
    return t ** levels


def symm_mults_exact(m: int, n: int, levels: int,
                     variant: str = "strassen") -> int:
    """Exact multiplication count of the flattened ``X @ Sym`` schedule on
    an (m, n) x (n, n) problem with ``m``, ``n`` already padded to the
    per-axis leaf-grid multiples of the algebra's <dm, dk, dn> split
    (the executor's padded shape): each of the ``symm_leaf_count``
    leaves is an (m/Bm, n/Bn) x (n/Bn, n/Bn) product.  Matches
    ``schedule.plan_symm(levels).mult_count(mb, nb)``
    (tests/test_properties.py)."""
    from .leaf_ir import algebra_dims
    dm, _dk, dn = algebra_dims(variant)
    bm, bn = dm ** levels, dn ** levels
    if m % bm or n % bn:
        raise ValueError(f"shape ({m}, {n}) not padded to the "
                         f"({bm}, {bn}) leaf grid at {levels} levels")
    return symm_leaf_count(levels, variant) * (m // bm) * (n // bn) ** 2


def ata_bwd_mults_exact(m: int, n: int, leaf: int = 32,
                        levels: int | None = None) -> int:
    """Multiplications of the fused Gram backward ``dA = A (S + S^t)``
    (a level-capped Strassen (m, n) x (n, n) product over the packed
    cotangent — ``kernels.strassen_fused.fused_symm_matmul``)."""
    return strassen_mults_exact(m, n, n, leaf, levels)


def classical_ata_bwd_mults(m: float, n: float) -> float:
    """Dense-dot baseline backward: ``A @ (S + S^t)`` at m n^2 products
    (the 2 m n^2-flop path the fused backward replaces)."""
    return m * n * n


def strassen_mults_exact(m: int, k: int, n: int, leaf: int = 32,
                         levels: int | None = None, _memo=None) -> int:
    """Exact multiplication count of (level-capped) Strassen on (m,k)x(k,n)."""
    if _memo is None:
        _memo = {}
    key = ("s", m, k, n, levels)
    if key in _memo:
        return _memo[key]
    if (levels is not None and levels <= 0) or min(m, k, n) <= leaf:
        res = m * k * n
    else:
        mp, kp, np_ = (m + 1) // 2, (k + 1) // 2, (n + 1) // 2
        lv = None if levels is None else levels - 1
        res = 7 * strassen_mults_exact(mp, kp, np_, leaf, lv, _memo)
    _memo[key] = res
    return res


# ---------------------------------------------------------------------------
# §4.1 — process-tree sizing
# ---------------------------------------------------------------------------

def npl(level: int) -> int:
    """Processes needed for `level` complete parallel levels (eq. 4)."""
    if level == 0:
        return 1
    if level == 1:
        return 6
    return 6 * 4 ** (level - 1) + 2 * sum(
        4 ** k * 7 ** (level - 1 - k) for k in range(level - 1)
    )


def lmax(p: int) -> int:
    """Max complete parallel levels with P processes (eq. 5)."""
    level = 0
    while npl(level + 1) <= p:
        level += 1
    return level


# ---------------------------------------------------------------------------
# §5 — communication model (latency + bandwidth along the critical path)
# ---------------------------------------------------------------------------

def latency_messages(p: int) -> int:
    """L(n, P): message count along the critical path."""
    lm = lmax(p)
    return max(4 * max(lm - 1, 0), 3 * lm)


def bandwidth_words(n: int) -> float:
    """BW(n, P) = (n/2)^2 words (paper: max message size independent of P)."""
    return (n / 2.0) ** 2


def comm_time(n: int, p: int, alpha: float, beta: float) -> float:
    """alpha * L + beta * BW (paper §5)."""
    return alpha * latency_messages(p) + beta * bandwidth_words(n)


# ---------------------------------------------------------------------------
# §6 — performance-metric model (speedup / efficiency / Karp-Flatt)
# ---------------------------------------------------------------------------

@dataclass
class ParallelModel:
    """Critical-path execution-time model for ATA-P.

    T(P) = serial_frac*T1 + (1-serial_frac)*T1/work_share(P) + comm(n, P)

    where work_share(P) is the effective concurrency: with lmax complete
    levels the slowest path is a HASA-P chain (branching 7, the heaviest
    child — paper §6.3.1 notes ATA-P children idle while HASA-P children
    finish), so effective speedup of the compute phase at complete levels is
    work/critical-path-work. Between complete levels, extra processes only
    shave the incomplete level partially (paper Fig 5 plateaus).
    """
    t1: float              # measured/modeled serial time (seconds)
    n: int                 # matrix dimension
    alpha: float = 2e-6    # per-message latency (s) — Galileo-class IB
    beta: float = 1.3e-9   # per-word time (s) ~ 6 GB/s fp64 effective
    serial_frac: float = 0.004  # paper Fig 8: e small, ~0.4%

    def critical_path_fraction(self, p: int) -> float:
        """Fraction of total work on the critical path, from the recursion:
        one ATA level splits work into 4 ATA shares (4/14 of the FLOPs... we
        use the measured 2:7 cost ratio — each HASA call costs ~(7/2)x an ATA
        call at the same level, paper §6.3.1) onto 6 processes."""
        lm = lmax(p)
        if lm == 0:
            return 1.0
        # Work split at an ATA level: total = 4*w_ata + 2*w_hasa,
        # w_hasa = 3.5 * w_ata  => critical child share = 3.5/11.
        ata_child, hasa_child = 1.0 / 11.0, 3.5 / 11.0
        frac = 1.0
        for _ in range(lm):
            frac *= hasa_child  # HASA child dominates the level
        # At HASA sub-levels the 7 children split evenly (1/7 each), already
        # accounted: hasa_child at the next level = its own subtree split.
        # Incomplete level: leftover processes shave the critical path by the
        # pairing factor k+1 (paper §4.1) on the last level only.
        extra = p - npl(lm)
        if extra > 0:
            k = extra // npl(lm)
            if k > 0:
                frac /= (k + 1)
        return frac

    def time(self, p: int) -> float:
        if p <= 1:
            return self.t1
        frac = self.critical_path_fraction(p)
        t_par = self.serial_frac * self.t1 + (1 - self.serial_frac) * self.t1 * frac
        return t_par + comm_time(self.n, p, self.alpha, self.beta)

    def speedup(self, p: int) -> float:
        return self.t1 / self.time(p)

    def efficiency(self, p: int) -> float:
        return self.speedup(p) / p

    def karp_flatt(self, p: int) -> float:
        s = self.speedup(p)
        return (1.0 / s - 1.0 / p) / (1.0 - 1.0 / p)


# ---------------------------------------------------------------------------
# §4 + §5 — critical-path SIMULATOR of the ATA-P process tree
# ---------------------------------------------------------------------------

@dataclass
class SimParams:
    """Per-multiplication throughput + comm constants (Galileo-class).

    ``mem_contention``: Galileo nodes are 2x18-core Broadwell; when a node
    is fully populated, shared memory bandwidth roughly halves the
    per-process multiply-accumulate rate vs a lone process (STREAM-class
    scaling). The serial baseline T(1) runs uncontended, so parallel runs
    carry factor (1 + c*(min(P, cores)-1)/(cores-1)).
    """
    sec_per_mult: float = 6.7e-10   # fitted to Broadwell-node ATA serial rate
    alpha: float = 2e-6            # per-message latency (s)
    beta: float = 1.3e-9           # per-word transfer (s) ~6 GB/s fp64
    overhead: float = 0.04         # per-level fork/join + imbalance fraction
    mem_contention: float = 0.57   # full-node slowdown factor - 1
    cores_per_node: int = 36
    # paper §6.3.1: incomplete parallel levels leave ATA-P processes idle
    # while HASA-P children finish ("highest time difference ... P=12, 18")
    incomplete_overhead: float = 0.20
    # Algorithm 1 line 5 "Initialize A_ij" + the cache-oblivious transposes
    # of A12/A22 (§3) + C patching run in the parent BEFORE/AFTER forking —
    # a serial per-level term (copies/elem * 8 B at node memory bandwidth,
    # sharing the same contention factor). 6 copies/elem fitted; the three
    # constants (contention, copies, incomplete idle) are fitted ONCE
    # against {S(6), S(250), E(250)} and validated on everything else.
    init_copies_per_elem: float = 6.0
    mem_bw: float = 12e9


def simulate_ata_p(n: int, p: int, sp: SimParams = SimParams(),
                   leaf: int = 32, m: int | None = None) -> float:
    """Critical-path execution time of ATA-P(n, P) per the paper's process
    tree (§4): complete levels fan ATA->4xATA+2xHASA (6 procs) and
    HASA->7xHASA, lefties pair onto the heaviest children (HASA first,
    larger subproblems next); per ATA level 3 concurrent reductions + 2
    sends of (n/2)^2 words; per HASA level 4 reductions + 3 sends.
    """
    m = n if m is None else m
    memo: dict = {}
    # ranks spread evenly over ceil(P/cores) nodes (SLURM default)
    nodes = -(-p // sp.cores_per_node)
    per_node = p / nodes
    contention = 1.0 + sp.mem_contention * (per_node - 1) \
        / max(sp.cores_per_node - 1, 1)
    spm = sp.sec_per_mult * contention

    def w_ata(mm, nn):
        return ata_mults_exact(mm, nn, leaf, None, memo) * spm

    def w_hasa(mm, kk, nn):
        return strassen_mults_exact(mm, kk, nn, leaf, None, memo) * spm

    def split_ata(g):
        """Paper §4.1: ATA-P children get [npl(x)]*4 + [7^x]*2 processes
        for the deepest complete level x = lmax(g)-1; lefties pair k-each
        onto every process (multiplying each subtree), remainder goes to
        HASA children first, then larger subproblems."""
        level = lmax(g)
        base = [npl(level - 1)] * 4 + [7 ** (level - 1)] * 2
        total = npl(level)
        lefties = g - total
        k = lefties // total
        alloc = [b * (1 + k) for b in base]
        rem = lefties - k * total
        for i in (4, 5, 0, 1, 2, 3):       # HASA first, then ATA children
            take = min(rem, base[i])
            alloc[i] += take
            rem -= take
            if rem <= 0:
                break
        return alloc

    def split_hasa(g):
        level = 0
        while 7 ** (level + 1) <= g:
            level += 1
        base = [7 ** (level - 1) if level else 1] * 7
        total = 7 ** level
        lefties = g - total
        k = lefties // total
        alloc = [b * (1 + k) for b in base]
        rem = lefties - k * total
        for i in range(7):
            take = min(rem, base[i])
            alloc[i] += take
            rem -= take
        return alloc

    def lpt_makespan(jobs, g):
        """Whole-job LPT schedule of child subproblems on g processes —
        the paper's processes own whole recursive calls, so with fewer
        processes than children the binding constraint is the makespan,
        not work/g."""
        loads = [0.0] * g
        for w in sorted(jobs, reverse=True):
            loads[loads.index(min(loads))] += w
        return max(loads)

    def t_ata(mm, nn, g):
        if g <= 1 or mm <= leaf or nn <= leaf:
            return w_ata(mm, nn)
        m1, m2 = (mm + 1) // 2, mm // 2
        n1, n2 = (nn + 1) // 2, nn // 2
        kids = [("a", m1, n1), ("a", m2, n1), ("a", m1, n2), ("a", m2, n2),
                ("h", n2, m1, n1), ("h", n2, m2, n1)]
        if g < 6:
            # not enough for a complete level: whole child calls are
            # packed onto the g processes (LPT makespan) + the paper's
            # incomplete-level idle-wait penalty (§6.3.1)
            return lpt_makespan([_w(kid) for kid in kids], g) \
                * (1 + sp.overhead) * (1 + sp.incomplete_overhead)
        alloc = split_ata(g)
        t_kids = []
        for kid, gk in zip(kids, alloc):
            if kid[0] == "a":
                t_kids.append(t_ata(kid[1], kid[2], gk))
            else:
                t_kids.append(t_hasa(kid[1], kid[2], kid[3], gk))
        comm = 2 * sp.alpha + sp.beta * (nn / 2) ** 2   # 3 reduc + 2 sends,
        init = mm * nn * sp.init_copies_per_elem * 8 / sp.mem_bw * contention
        return (max(t_kids) + comm + init) * (1 + sp.overhead)

    def t_hasa(mm, kk, nn, g):
        if g <= 1 or min(mm, kk, nn) <= leaf:
            return w_hasa(mm, kk, nn)
        m2, k2, n2 = (mm + 1) // 2, (kk + 1) // 2, (nn + 1) // 2
        if g < 7:
            return lpt_makespan([w_hasa(m2, k2, n2)] * 7, g) \
                * (1 + sp.overhead) * (1 + sp.incomplete_overhead)
        alloc = split_hasa(g)
        t_kids = [t_hasa(m2, k2, n2, gk) for gk in alloc]
        comm = 3 * sp.alpha + sp.beta * (nn / 2) ** 2   # 4 reduc + 3 sends
        init = (mm * kk + kk * nn) * sp.init_copies_per_elem * 8 \
            / sp.mem_bw * contention
        return (max(t_kids) + comm + init) * (1 + sp.overhead)

    def _w(kid):
        if kid[0] == "a":
            return w_ata(kid[1], kid[2])
        return w_hasa(kid[1], kid[2], kid[3])

    return t_ata(m, n, p)


def simulate_metrics(n: int, ps, sp: SimParams = SimParams()) -> dict:
    """speedup / efficiency / Karp-Flatt across process counts."""
    t1 = simulate_ata_p(n, 1, sp)
    out = {"t1": t1, "rows": []}
    for p in ps:
        t = simulate_ata_p(n, p, sp)
        s = t1 / t
        e = s / p
        kf = (1.0 / s - 1.0 / p) / (1.0 - 1.0 / p) if p > 1 else 0.0
        out["rows"].append({"P": p, "time": t, "speedup": s,
                            "efficiency": e, "karp_flatt": kf})
    return out


# TPU v5e hardware constants (roofline; see launch/dryrun + roofline pkg).
TPU_V5E_BF16_FLOPS = 197e12       # per chip
TPU_V5E_HBM_BW = 819e9            # bytes/s
TPU_V5E_ICI_BW = 50e9             # bytes/s per link
TPU_V5E_ICI_LATENCY = 1e-6        # per collective round (s), order of mag


def pipelined_bytes_score(read_bytes: float, write_bytes: float,
                          flops: float, *, pipeline_depth: int = 1,
                          grid_steps: int = 1,
                          flop_rate: float = TPU_V5E_BF16_FLOPS,
                          hbm_bw: float = TPU_V5E_HBM_BW) -> float:
    """Roofline score (HBM-byte-equivalents) of a bound leaf program under
    DMA pipelining (DESIGN.md §16).

    Unpipelined (depth <= 1), each grid step serializes its operand DMA
    against its MXU work, so the cost is the SUM of the memory and
    compute terms.  With revolving buffers (depth >= 2) the next step's
    copies stream while the current step computes, so steady state pays
    the MAX of the two, plus one non-overlapped pipeline fill amortized
    over ``grid_steps``.  Compute is expressed in byte-equivalents
    (``flops * hbm_bw / flop_rate``) so the score stays comparable with
    the raw ``read_bytes + write_bytes`` ranking autotune used before
    this term existed."""
    mem = float(read_bytes) + float(write_bytes)
    cmp_eq = float(flops) * hbm_bw / flop_rate
    if pipeline_depth <= 1:
        return mem + cmp_eq
    fill = min(mem, cmp_eq) / max(int(grid_steps), 1)
    return max(mem, cmp_eq) + fill


# ---------------------------------------------------------------------------
# Distributed-gram communication model (beyond-paper; DESIGN.md §5).
#
# Per-device wire traffic and sequential message rounds of each
# ``core.distributed`` scheme, as closed forms in (m, n, R, T, c, dtype) —
# R = row-axis size, T = ring/col-axis size, c = replication factor.
# Collectives are costed with the standard ring algorithms (the same model
# ``roofline.hlo_census.collective_census`` applies per instruction, so
# modeled and measured volumes are directly comparable):
#
#   all-reduce of V bytes over g devices:  2 V (g-1)/g   wire, 2(g-1) rounds
#   reduce-scatter:                          V (g-1)/g   wire,  (g-1) rounds
#   collective-permute:                      V            wire,   1    round
#
# The per-device compute term (MAC flops) is included because the schemes
# engage different device counts on the same mesh: the row-only schemes
# leave the col/rep axes idle (replicated compute), the ring splits work
# R*T ways, and bfs25d splits the ring's block tasks a further c ways.
# ---------------------------------------------------------------------------

GRAM_SCHEMES = ("allreduce", "reducescatter", "ring", "bfs25d")


@dataclass
class GramCommCost:
    """Per-device cost of one distributed-gram scheme instance."""
    scheme: str
    devices: int            # devices engaged by the scheme's collectives
    wire_bytes: float       # per-device bytes on the wire (ring model)
    messages: int           # sequential collective rounds (latency term)
    flops: float            # per-device MAC flops (incl. duplicated work)
    mem_input_factor: int   # input replication (c for bfs25d, else 1)

    def time(self, *, alpha: float = TPU_V5E_ICI_LATENCY,
             ici_bw: float = TPU_V5E_ICI_BW,
             flop_rate: float = TPU_V5E_BF16_FLOPS) -> float:
        """alpha * rounds + bytes / bw + flops / rate."""
        return (alpha * self.messages + self.wire_bytes / ici_bw
                + self.flops / flop_rate)


def gram_comm_cost(scheme: str, m: int, n: int, *, rows: int = 1,
                   ring: int | None = None, rep: int | None = None,
                   dtype_bytes: int = 4,
                   out_bytes: int | None = None) -> GramCommCost:
    """Cost of ``scheme`` for an (m, n) A on axis sizes (rows=R, ring=T,
    rep=c).  ``ring``/``rep`` are ignored by the schemes that do not use
    those axes (their compute is *replicated* there, which the flops term
    deliberately does not discount).

    ``dtype_bytes`` is the width of A — what the ring family's
    ``ppermute``s ship; ``out_bytes`` (default: same) is the wire width
    of C — what every reduction ships.  They differ when the caller
    reduces in a higher precision than the input (bf16 A, fp32 C), and
    charging both at the output width would overcharge the ring family's
    permute phase 2x."""
    R = max(int(rows), 1)
    b_in = float(dtype_bytes)
    b_out = float(dtype_bytes if out_bytes is None else out_bytes)
    total_macs = 2.0 * m * n * n / 2.0        # tril gram: ~m n^2 / 2 MACs x2

    if scheme == "allreduce":
        return GramCommCost(
            scheme=scheme, devices=R,
            wire_bytes=2.0 * n * n * b_out * (R - 1) / R,
            messages=2 * (R - 1),
            flops=total_macs / R, mem_input_factor=1)
    if scheme == "reducescatter":
        return GramCommCost(
            scheme=scheme, devices=R,
            wire_bytes=1.0 * n * n * b_out * (R - 1) / R,
            messages=R - 1,
            flops=total_macs / R, mem_input_factor=1)

    if ring is None or ring < 1:
        raise ValueError(f"scheme {scheme!r} needs ring=T")
    T = int(ring)
    half = T // 2
    m_loc, n_loc = m / R, n / T
    # per-device block work: diagonal ATA (~half the MACs of a full block
    # product) + `half` off-diagonal Strassen blocks, reduced over rows.
    blk_macs = 2.0 * m_loc * n_loc * n_loc

    if scheme == "ring":
        permute = half * m_loc * n_loc * b_in
        stack = (half + 1) * n_loc * n_loc * b_out
        return GramCommCost(
            scheme=scheme, devices=R * T,
            wire_bytes=permute + 2.0 * stack * (R - 1) / R,
            messages=half + 2 * (R - 1),
            flops=blk_macs * (half + 0.5), mem_input_factor=1)

    if scheme == "bfs25d":
        c = max(int(rep or 1), 1)
        n_off = -(-half // c)                 # ceil(half / c)
        g = c * R                             # merge-psum group size
        # one skew + (n_off - 1) stride-c hops = n_off permutes total
        permute = n_off * m_loc * n_loc * b_in
        stack = (half + 1) * n_loc * n_loc * b_out
        return GramCommCost(
            scheme=scheme, devices=R * T * c,
            wire_bytes=permute + 2.0 * stack * (g - 1) / g,
            messages=n_off + 2 * (g - 1),
            # each group: its n_off Strassen tasks + the duplicated diagonal
            flops=blk_macs * (n_off + 0.5), mem_input_factor=c)

    raise ValueError(f"unknown scheme {scheme!r}")


def rank_gram_schemes(m: int, n: int, *, rows: int = 1,
                      ring: int | None = None, rep: int | None = None,
                      dtype_bytes: int = 4,
                      out_bytes: int | None = None,
                      alpha: float = TPU_V5E_ICI_LATENCY,
                      ici_bw: float = TPU_V5E_ICI_BW,
                      flop_rate: float | None = None,
                      schemes=None) -> list[GramCommCost]:
    """Feasibility-agnostic ranking (cheapest modeled time first) of the
    requested ``schemes`` (default: every scheme the axis sizes allow).

    ``flop_rate`` defaults to the dtype-matched MXU rate (bf16 peak
    scaled by 2/dtype_bytes — fp32 runs at roughly half the bf16 rate),
    so the compute term is weighted consistently with the dtype-correct
    wire term; schemes engage different device counts, so a mismatched
    rate would bias the ranking non-uniformly."""
    if flop_rate is None:
        flop_rate = TPU_V5E_BF16_FLOPS * 2.0 / max(dtype_bytes, 2)
    if schemes is None:
        schemes = ["allreduce", "reducescatter"]
        if ring:
            schemes.append("ring")
            if rep:
                schemes.append("bfs25d")
    costs = [gram_comm_cost(s, m, n, rows=rows, ring=ring, rep=rep,
                            dtype_bytes=dtype_bytes, out_bytes=out_bytes)
             for s in schemes]
    return sorted(costs, key=lambda cst: cst.time(
        alpha=alpha, ici_bw=ici_bw, flop_rate=flop_rate))


def choose_gram_scheme(m: int, n: int, **kw) -> str:
    """The cheapest scheme per :func:`rank_gram_schemes`."""
    return rank_gram_schemes(m, n, **kw)[0].scheme
