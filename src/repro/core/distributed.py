"""Distributed ATA — the paper's ATA-P mapped onto a JAX SPMD mesh.

Paper (§4): a dynamic MPI process tree — each complete parallel level of
ATA-P fans out to 6 processes (4x ATA + 2x HASA), communicators perform
3 simultaneous MPI reductions (the two addends of C11, C22, C21), then
point-to-point sends patch C together on the subtree root.

TPU adaptation (DESIGN.md §2): TPU pods are SPMD machines — the process tree
becomes a mesh decomposition and the reductions become axis collectives:

* ``gram_allreduce`` — paper-faithful scheme. A is sharded by *rows* over
  ``row_axis`` (the recursion over m: C = sum_r A_r^t A_r — exactly the
  C11/C22 two-addend reduction generalized to P addends). Each device runs
  the sequential ATA recursion on its shard; one ``psum`` realizes the
  paper's reduction tree. Latency: one collective — matching the paper's
  claim of minimal message count; bandwidth: n^2 words (the paper's
  BW = (n/2)^2 per message, and like the paper it is independent of P).

* ``gram_reducescatter`` — beyond-paper: same compute, but the reduction
  emits C sharded by block-rows (``psum_scatter``), cutting the per-device
  bandwidth term by P and never materializing C replicated.

* ``gram_ring`` — beyond-paper: A sharded by rows *and* columns
  (``row_axis`` x ``col_axis``). Diagonal blocks use ATA (half work);
  off-diagonal blocks use Strassen — the exact ATA/HASA division of labor
  of the paper — scheduled as a **half-ring**: because C is symmetric, only
  floor(T/2)+1 ring steps are needed (vs T for a generic A^tB collective
  matmul). Each step's ``ppermute`` overlaps with the previous step's block
  product (collective-matmul pattern), turning the paper's blocking
  Send/Recv into bandwidth-optimal, compute-overlapped ICI traffic.

* ``gram_bfs25d`` — communication-avoiding 2.5D variant (Ballard et al.,
  arXiv:1202.3173; Benson & Ballard, arXiv:1409.2908): a third mesh axis
  ``rep_axis`` of size c replicates A (the 2.5D memory-for-communication
  trade), and the half-ring's independent Strassen/HASA block tasks are
  dealt out BFS-style (CAPS's breadth-first step) across the c replication
  groups — group r takes ring steps ``s ≡ r+1 (mod c)``.  Each group skews
  its A copy once (one ``ppermute`` jump over (rep, col)) and then hops by
  c, so the ring-permute rounds on the critical path drop from floor(T/2)
  to ceil(floor(T/2)/c) while each task still falls into the same fused
  local kernel (ATA diagonal, Strassen off-diagonal).  A final ``psum``
  over (rep, row) — small payload: the packed block stack, not A — merges
  the groups' disjoint block stacks into the half-ring layout of
  ``gram_ring``.

All four run inside ``shard_map``; ``distributed_gram`` is the pjit-level
wrapper over a globally-sharded A, and ``scheme="auto"`` picks the scheme
by the communication cost model in ``core.cost_model``
(``rank_gram_schemes``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .ata import ata, ata_full
from .strassen import strassen_matmul
from .symmetry import symmetrize_from_lower

__all__ = [
    "gram_allreduce", "gram_reducescatter", "gram_ring", "gram_bfs25d",
    "distributed_gram", "ring_layout_coords", "assemble_ring_gram",
    "ring_stack_len", "feasible_schemes", "default_gram_axes",
    "scheme_fallback_chain", "shrink_mesh", "SCHEME_LADDER",
    "shard_map_compat",
]

# Degradation order for the serving layer's scheme fallback: most
# communication-avoiding (and most moving parts) first, the
# paper-faithful single-psum scheme last — each step rightward trades
# bandwidth optimality for fewer ways to fail (fewer collectives, fewer
# axes involved).
SCHEME_LADDER = ("bfs25d", "ring", "reducescatter", "allreduce")


def shard_map_compat():
    """``(shard_map, unchecked_kwargs)`` across jax versions.

    Resolves the import location (``jax.shard_map`` vs the 0.4.x
    experimental module) and the ``check_rep`` -> ``check_vma`` kwarg
    rename *independently* — the import path does not imply the kwarg
    set, so the kwarg is keyed on the function signature.  Single shared
    shim for every shard_map call site in the repo.
    """
    import inspect
    try:
        from jax import shard_map as sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):
        params = {}
    if "check_vma" in params:
        unchecked = {"check_vma": False}
    elif "check_rep" in params:
        unchecked = {"check_rep": False}
    else:
        unchecked = {}
    return sm, unchecked


# ---------------------------------------------------------------------------
# shard_map bodies (take *local* shards, use collectives explicitly)
# ---------------------------------------------------------------------------

def gram_allreduce(a_local: jax.Array, row_axis: str, *,
                   levels=2, leaf: int = 256,
                   variant: str = "strassen", mode: str = "auto",
                   out_dtype=None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Paper-faithful: local ATA + one all-reduce over the row axis.

    Per-shard compute goes through the fused leaf-task pipeline on TPU
    (mode="auto"; see ata.py) — the collective schedule is unchanged.
    ``out_dtype`` defaults to the *input* dtype here (unlike plain
    ``ata``): accumulation is still fp32 inside the kernel, but the
    reduction moves C over the wire, and shipping bf16 cells as fp32
    would silently double the paper's bandwidth term.  Pass
    ``out_dtype=jnp.float32`` to reduce in full precision.
    Returns the full symmetric C, replicated over ``row_axis``.
    """
    c_local = ata_full(a_local, levels=levels, leaf=leaf, variant=variant,
                       mode=mode, interpret=interpret,
                       out_dtype=out_dtype or a_local.dtype)
    return jax.lax.psum(c_local, row_axis)


def gram_reducescatter(a_local: jax.Array, row_axis: str, *,
                       levels=2, leaf: int = 256,
                       variant: str = "strassen", mode: str = "auto",
                       out_dtype=None,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Beyond-paper: local ATA + reduce-scatter (C sharded by rows over
    ``row_axis``); bandwidth term / P, no replicated C."""
    c_local = ata_full(a_local, levels=levels, leaf=leaf, variant=variant,
                       mode=mode, interpret=interpret,
                       out_dtype=out_dtype or a_local.dtype)
    return jax.lax.psum_scatter(c_local, row_axis, scatter_dimension=0,
                                tiled=True)


def gram_ring(a_local: jax.Array, col_axis: str,
              row_axis: Optional[str] = None, *,
              levels=2, leaf: int = 256,
              variant: str = "strassen", mode: str = "auto",
              out_dtype=None, axis_size: Optional[int] = None,
              interpret: Optional[bool] = None) -> jax.Array:
    """Half-ring symmetric collective gram (beyond-paper TPU schedule).

    Device layout: ``a_local`` is the (rows/R, cols/T) shard of A.
    Step 0 computes the diagonal block with ATA (the paper's symmetric
    recursion, half work); step s rotates column blocks by one hop around
    ``col_axis`` and computes one off-diagonal block with Strassen (the
    paper's HASA role). Symmetry halves the ring: floor(T/2) hops.

    Returns a stack of local blocks, shape (floor(T/2)+1, n_loc, n_loc):
    entry s on device c is C[c, (c - s) % T] (lower-circulant layout; see
    ``ring_layout_coords``), already reduced over ``row_axis`` if given.
    """
    # The ring length must be static (it drives the Python hop loop);
    # jax.lax.axis_size is missing on older jax, so callers that know the
    # mesh (distributed_gram) pass it explicitly.
    if axis_size is not None:
        T = axis_size
    elif hasattr(jax.lax, "axis_size"):
        T = jax.lax.axis_size(col_axis)
    else:
        raise ValueError(
            "gram_ring needs a static ring length and this jax version has "
            "no jax.lax.axis_size — pass axis_size=mesh.shape[col_axis]")
    c = jax.lax.axis_index(col_axis)
    n_loc = a_local.shape[1]
    half = T // 2

    perm = [(i, (i + 1) % T) for i in range(T)]

    # Step 0: diagonal block — symmetric, use ATA (half the multiplications).
    out_dtype = out_dtype or a_local.dtype   # wire dtype (see gram_allreduce)
    blocks = [ata_full(a_local, levels=levels, leaf=leaf, variant=variant,
                       mode=mode, out_dtype=out_dtype,
                       interpret=interpret)]

    cur = a_local
    for s in range(1, half + 1):
        # Issue the rotate for this step; XLA's async collective-permute
        # overlaps it with the *previous* iteration's block product because
        # there is no data dependence between them.
        cur = jax.lax.ppermute(cur, col_axis, perm)
        # Device c now holds column block (c - s) % T.  The A_loc^t
        # operand runs through the leaf-program executor's trans_a index
        # maps — no transposed copy of the shard in HBM (reference mode
        # materializes it, as before).
        blk = strassen_matmul(a_local, cur, trans_a=True, levels=levels,
                              leaf=leaf, variant=variant, mode=mode,
                              out_dtype=out_dtype, interpret=interpret)
        if s == half and T % 2 == 0:
            # At the antipodal step each unordered pair {c, c-T/2} appears on
            # both devices: keep it only on c < T/2 (SPMD runs the same
            # program everywhere; masking is the "incomplete level" analogue).
            # jnp.where, not multiply-by-mask: 0 * Inf = NaN would let a
            # non-finite discarded block poison the stack (and, under
            # bfs25d, the psum that merges group stacks).
            blk = jnp.where(c < half, blk, jnp.zeros_like(blk))
        blocks.append(blk)

    out = jnp.stack(blocks)  # (half+1, n_loc, n_loc)
    if row_axis is not None:
        out = jax.lax.psum(out, row_axis)
    return out


def ring_stack_len(T: int) -> int:
    """Stack entries of the half-ring layout: floor(T/2) + 1."""
    return T // 2 + 1


def gram_bfs25d(a_local: jax.Array, col_axis: str, rep_axis: str,
                row_axis: Optional[str] = None, *,
                levels=2, leaf: int = 256,
                variant: str = "strassen", mode: str = "auto",
                out_dtype=None, col_size: Optional[int] = None,
                rep_size: Optional[int] = None,
                interpret: Optional[bool] = None) -> jax.Array:
    """Communication-avoiding 2.5D half-ring gram (see module docstring).

    Device layout: ``a_local`` is the (rows/R, cols/T) shard of A,
    *replicated* over ``rep_axis`` (size c) — the 2.5D extra-memory axis.
    The half-ring's block tasks are distributed BFS-style over the c
    replication groups:

    * step 0 (diagonal, ATA — the paper's symmetric half-work recursion)
      is computed by every group (SPMD) and kept on group 0 only;
    * off-diagonal step s in 1..floor(T/2) (Strassen — the paper's HASA
      role) belongs to group (s-1) mod c.  Group r reaches its first step
      with ONE skewing ``ppermute`` over the combined (rep, col) axes
      (rotation by r+1 inside each group's ring) and then advances by c
      hops per ``ppermute``, so each group performs only
      ``ceil(floor(T/2)/c)`` sequential hops.

    Each group scatters its blocks into disjoint slots of an oversized
    stack (slot = global ring step; groups own disjoint residues mod c,
    masked slots hold exact zeros via ``jnp.where``), and one ``psum``
    over (rep, row) merges the stacks.  The result is identical in
    layout to ``gram_ring``: shape (floor(T/2)+1, n_loc, n_loc), entry s
    on ring device d is C[d, (d - s) % T] (``ring_layout_coords``),
    replicated over ``rep_axis``.
    """
    if col_size is None or rep_size is None:
        raise ValueError(
            "gram_bfs25d needs static col_size/rep_size — pass "
            "mesh.shape[col_axis] and mesh.shape[rep_axis]")
    T, c = col_size, rep_size
    half = T // 2
    n_off = -(-half // c)              # sequential hops per group
    r = jax.lax.axis_index(rep_axis)
    d = jax.lax.axis_index(col_axis)
    n_loc = a_local.shape[1]
    out_dtype = out_dtype or a_local.dtype   # wire dtype (see gram_allreduce)

    # Diagonal (ATA): computed by every replication group — the block is
    # 1 of the ~half/c + 1 per-device tasks, so the duplication is bounded
    # — and kept on group 0 only (jnp.where: exact zeros elsewhere, a
    # correctness requirement for the merging psum below).
    diag = ata_full(a_local, levels=levels, leaf=leaf, variant=variant,
                    mode=mode, out_dtype=out_dtype, interpret=interpret)
    diag = jnp.where(r == 0, diag, jnp.zeros_like(diag))

    # Oversized stack: slot s holds ring step s; slots beyond ``half``
    # only ever receive masked (zero) blocks and are sliced off before the
    # psum.  Sized so every group's last write index (n_off*c) is in
    # bounds — dynamic_update_slice must never clamp.
    stack = jnp.zeros((1 + n_off * c, n_loc, n_loc), out_dtype)
    stack = stack.at[0].set(diag)

    if n_off > 0:
        # Skew: group r starts at step s0 = r + 1.  One ppermute over the
        # *combined* (rep, col) axes realizes all groups' different
        # rotations at once (linear index = rep * T + col).
        skew = []
        for rr in range(c):
            for j in range(T):
                skew.append((rr * T + j, rr * T + (j + rr + 1) % T))
        cur = jax.lax.ppermute(a_local, (rep_axis, col_axis), skew)
        hop = [(i, (i + c) % T) for i in range(T)]
        for t in range(n_off):
            if t > 0:
                # Advance every group by c hops in one message; XLA's async
                # collective-permute overlaps it with the previous block
                # product (same pattern as gram_ring).
                cur = jax.lax.ppermute(cur, col_axis, hop)
            s = r + 1 + t * c          # this group's global ring step
            blk = strassen_matmul(a_local, cur, trans_a=True, levels=levels,
                                  leaf=leaf, variant=variant, mode=mode,
                                  out_dtype=out_dtype, interpret=interpret)
            valid = s <= half
            if T % 2 == 0:
                # antipodal dedup, as in gram_ring (jnp.where — see there)
                valid = valid & ((s != half) | (d < half))
            blk = jnp.where(valid, blk, jnp.zeros_like(blk))
            stack = jax.lax.dynamic_update_slice(
                stack, blk[None].astype(out_dtype), (s, 0, 0))

    out = stack[:half + 1]
    axes = (rep_axis,) if row_axis is None else (rep_axis, row_axis)
    return jax.lax.psum(out, axes)


def ring_layout_coords(T: int) -> list[tuple[int, int, int]]:
    """(device, step, global_block_row, global_block_col) ownership map of
    the half-ring layout, as (c, s, i, j) with (i, j) in the lower triangle."""
    coords = []
    half = T // 2
    for dev in range(T):
        for s in range(half + 1):
            if s == half and T % 2 == 0 and dev >= half:
                continue  # masked duplicate
            j = (dev - s) % T
            i, jj = (dev, j) if dev >= j else (j, dev)  # mirror wraps upper
            coords.append((dev, s, i, jj))
    return coords


# ---------------------------------------------------------------------------
# pjit-level wrapper
# ---------------------------------------------------------------------------

def default_gram_axes(mesh: Mesh) -> dict:
    """Map a mesh onto ``distributed_gram``'s (row, col, rep) axis kwargs
    by the repo's naming convention — "data" rows, "model" ring, "rep"
    replication — falling back to positional order for foreign names."""
    names = list(mesh.axis_names)
    row = "data" if "data" in names else next(
        (a for a in names if a != "rep"), names[0])
    # never reuse the row axis as the ring axis (a ("model",)-only mesh
    # has row == "model"; P(row, row) in_specs would fail at compile time)
    col = "model" if ("model" in names and row != "model") else next(
        (a for a in names if a not in (row, "rep")), None)
    rep = "rep" if "rep" in names else None
    return {"row_axis": row, "col_axis": col, "rep_axis": rep}


def feasible_schemes(m: int, n: int, mesh: Mesh, *,
                     row_axis: str = "data",
                     col_axis: Optional[str] = None,
                     rep_axis: Optional[str] = None) -> list[str]:
    """Schemes runnable for an (m, n) A on ``mesh`` with the given axes
    (shard_map divisibility + axis availability)."""
    sizes = dict(mesh.shape)
    out = []
    if row_axis in sizes and m % sizes[row_axis] == 0:
        out += ["allreduce"]
        if n % sizes[row_axis] == 0:
            out += ["reducescatter"]
        if col_axis in sizes and n % sizes[col_axis] == 0:
            out += ["ring"]
            if rep_axis in sizes:
                out += ["bfs25d"]
    return out


def scheme_fallback_chain(m: int, n: int, mesh: Mesh, *,
                          scheme: str = "auto",
                          row_axis: str = "data",
                          col_axis: Optional[str] = None,
                          rep_axis: Optional[str] = None,
                          dtype_bytes: int = 4,
                          out_bytes: Optional[int] = None) -> list[str]:
    """Ordered list of schemes the serving layer should try for an
    (m, n) gram on ``mesh``: the preferred scheme first (the cost-model
    winner under ``scheme="auto"``, else ``scheme`` itself when
    feasible), then every other feasible scheme in ``SCHEME_LADDER``
    order — strictly degrading toward the paper-faithful allreduce.
    Empty when nothing is feasible (callers fall back to local)."""
    feas = feasible_schemes(m, n, mesh, row_axis=row_axis,
                            col_axis=col_axis, rep_axis=rep_axis)
    if not feas:
        return []
    if scheme == "auto":
        from . import cost_model
        sizes = dict(mesh.shape)
        ranked = cost_model.rank_gram_schemes(
            m, n,
            rows=sizes.get(row_axis, 1),
            ring=sizes.get(col_axis) if col_axis else None,
            rep=sizes.get(rep_axis) if rep_axis else None,
            dtype_bytes=dtype_bytes,
            out_bytes=out_bytes if out_bytes is not None else dtype_bytes,
            schemes=feas)
        head = ranked[0].scheme
    else:
        head = scheme if scheme in feas else None
    chain = [] if head is None else [head]
    chain += [s for s in SCHEME_LADDER if s in feas and s not in chain]
    return chain


def shrink_mesh(mesh: Mesh, axis: Optional[str] = None) -> Optional[Mesh]:
    """The surviving sub-mesh after losing one slice of ``axis`` (a dead
    replica group): same axis names, ``axis`` one shorter — slice 0 of
    ``axis`` is dropped, mirroring "the failed group's devices are gone".

    ``axis=None`` picks for least damage: the replication axis when it
    has size > 1 (bfs25d degrades to smaller c — or to plain ring at
    c=1 — with no resharding of the row/col layout), else the largest
    axis.  Returns None when the mesh is a single device (nothing left
    to shrink — the serving layer goes fully local).
    """
    sizes = dict(mesh.shape)
    if axis is None:
        if sizes.get("rep", 1) > 1:
            axis = "rep"
        else:
            axis = max(sizes, key=lambda a: sizes[a])
    if sizes.get(axis, 1) <= 1:
        shrinkable = [a for a, s in sizes.items() if s > 1]
        if not shrinkable:
            return None
        axis = max(shrinkable, key=lambda a: sizes[a])
    idx = mesh.axis_names.index(axis)
    devices = mesh.devices.take(range(1, sizes[axis]), axis=idx)
    return Mesh(devices, mesh.axis_names)


def distributed_gram(a: jax.Array, mesh: Mesh, *,
                     scheme: str = "allreduce",
                     row_axis: str = "data",
                     col_axis: Optional[str] = None,
                     rep_axis: Optional[str] = None,
                     levels=2, leaf: int = 256,
                     variant: str = "strassen", mode: str = "auto",
                     out_dtype=None,
                     interpret: Optional[bool] = None,
                     assemble: bool = True) -> jax.Array:
    """Compute C = A^t A for a globally sharded A on ``mesh``.

    scheme:
      "allreduce"      — paper-faithful (rows sharded, psum).  C replicated.
      "reducescatter"  — C sharded by rows over ``row_axis``.
      "ring"           — rows x cols sharded, half-ring schedule. With
                         ``assemble`` (testing/solvers) the dense C is
                         rebuilt replicated; production keeps the circulant
                         block layout (sharded over ``col_axis``) —
                         n(n+1)/2-ish storage, zero post-processing.
      "bfs25d"         — 2.5D: ring + a replication axis ``rep_axis`` that
                         distributes the Strassen block tasks BFS-style
                         across replication groups (fewer, larger
                         messages; c-fold A memory).  Same output layout
                         as "ring".
      "auto"           — rank the feasible schemes with
                         ``cost_model.rank_gram_schemes`` (bytes moved +
                         message count + per-device flops) and run the
                         cheapest.
    """
    shard_map, unchecked = shard_map_compat()

    if scheme == "auto":
        from . import cost_model
        cands = feasible_schemes(a.shape[0], a.shape[1], mesh,
                                 row_axis=row_axis, col_axis=col_axis,
                                 rep_axis=rep_axis)
        if not cands:
            raise ValueError(
                f"no feasible scheme for shape {a.shape} on mesh axes "
                f"{dict(mesh.shape)}")
        sizes = dict(mesh.shape)
        ranked = cost_model.rank_gram_schemes(
            a.shape[0], a.shape[1],
            rows=sizes.get(row_axis, 1),
            ring=sizes.get(col_axis) if col_axis else None,
            rep=sizes.get(rep_axis) if rep_axis else None,
            # ppermutes ship A (input dtype); reductions ship C (wire
            # dtype — the schemes default out_dtype to the input dtype)
            dtype_bytes=jnp.dtype(a.dtype).itemsize,
            out_bytes=jnp.dtype(out_dtype or a.dtype).itemsize,
            schemes=cands)
        scheme = ranked[0].scheme

    if scheme in ("allreduce", "reducescatter"):
        body = {
            "allreduce": gram_allreduce,
            "reducescatter": gram_reducescatter,
        }[scheme]
        fn = functools.partial(body, row_axis=row_axis, levels=levels,
                               leaf=leaf, variant=variant, mode=mode,
                               out_dtype=out_dtype, interpret=interpret)
        out_spec = P() if scheme == "allreduce" else P(row_axis)
        # named_scope: the resolved scheme lands in the HLO metadata, so
        # a profile (or HLO census) attributes traffic to the scheme the
        # cost model actually picked
        with jax.named_scope(f"gram_dist:{scheme}"):
            return shard_map(
                fn, mesh=mesh, in_specs=P(row_axis, None),
                out_specs=out_spec, **unchecked,
            )(a)

    if scheme in ("ring", "bfs25d"):
        if col_axis is None:
            raise ValueError(f"{scheme} scheme needs col_axis")
        T = mesh.shape[col_axis]
        n = a.shape[1]

        if scheme == "ring":
            def body(a_local):
                return gram_ring(a_local, col_axis, row_axis,
                                 levels=levels, leaf=leaf, variant=variant,
                                 mode=mode, out_dtype=out_dtype,
                                 axis_size=T, interpret=interpret)
        else:
            if rep_axis is None:
                raise ValueError("bfs25d scheme needs rep_axis")
            c = mesh.shape[rep_axis]

            def body(a_local):
                return gram_bfs25d(a_local, col_axis, rep_axis, row_axis,
                                   levels=levels, leaf=leaf, variant=variant,
                                   mode=mode, out_dtype=out_dtype,
                                   col_size=T, rep_size=c,
                                   interpret=interpret)

        with jax.named_scope(f"gram_dist:{scheme}"):
            stacks = shard_map(
                body, mesh=mesh,
                in_specs=P(row_axis, col_axis),
                # stack: (half+1, n/T, n/T) per device -> gather cols of
                # blocks
                out_specs=P(None, None, col_axis),
                **unchecked,
            )(a)
        if not assemble:
            return stacks        # production: circulant layout, sharded
        # stacks: (half+1, n/T, n) — device c's column of blocks at slot c.
        return assemble_ring_gram(stacks, T, n)

    raise ValueError(f"unknown scheme {scheme!r}")


def assemble_ring_gram(stacks: jax.Array, T: int, n: int) -> jax.Array:
    """Assemble the dense symmetric C from half-ring output.

    ``stacks``: (half+1, n_loc, n) where [:, :, c*n_loc:(c+1)*n_loc] is
    device c's block stack (entry s = C[c, (c-s)%T] contribution).
    """
    n_loc = n // T
    c = jnp.zeros((n, n), stacks.dtype)
    half = T // 2
    for dev in range(T):
        for s in range(half + 1):
            if s == half and T % 2 == 0 and dev >= half:
                continue
            blk = stacks[s, :, dev * n_loc:(dev + 1) * n_loc]  # C[dev, j]
            j = (dev - s) % T
            if dev >= j:
                c = jax.lax.dynamic_update_slice(c, blk, (dev * n_loc, j * n_loc))
            else:  # wrapped: this is C[dev, j] with j > dev — mirror it
                c = jax.lax.dynamic_update_slice(c, blk.T, (j * n_loc, dev * n_loc))
    return symmetrize_from_lower(jnp.tril(c))
