"""Leaf-task schedule: the ATA/HASA recursion flattened at trace time.

The reference recursion in ``ata.py``/``strassen.py`` materializes every
Strassen operand sum, all 7 ``M_i`` products and per-level ``pad``/
``concatenate`` copies in HBM.  This module removes the recursion entirely:
for a fixed ``levels`` the whole computation is *planned* ahead of time as a
flat list of leaf products, each of the form

    P = (sum_p s_p * A[r_p, c_p])^T  @  (sum_q t_q * A[r_q, c_q])

where ``A[r, c]`` is a leaf block of the (zero-padded) input on a
``2^levels x 2^levels`` grid, ``s_p, t_q`` are +-1 Strassen operand signs,
and each product carries a list of +-1-signed *destinations* — leaf blocks
of the lower triangle of C = A^t A.  Because C12 = C21^t is never computed
(paper Alg. 1), every destination satisfies ``di >= dj``.

The flattening rests on two identities:

* a quadrant of ``X^t`` is the transpose of the mirrored quadrant of ``X``,
  so Strassen operand sums over quadrants of ``A12^t`` are (transposes of)
  signed sums of sub-blocks of ``A`` — no transpose is ever materialized;
* Strassen recombination is linear with +-1 coefficients, so destinations
  compose level by level into +-1-signed leaf destinations.

``plan_ata(levels)`` / ``plan_matmul(levels)`` depend only on ``levels`` and
``variant`` (never on shapes), so plans are cached and shared across every
call site; the executor in ``repro.kernels.strassen_fused`` binds a plan to
concrete block sizes.  See DESIGN.md §4 for the memory model.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

__all__ = [
    "Product", "Contribution", "Plan",
    "plan_ata", "plan_matmul", "plan_symm",
    "evaluate_ata_plan", "evaluate_matmul_plan", "evaluate_symm_plan",
]

# A term is (row_block, col_block, sign) over the 2^levels leaf grid.
# Right-operand terms of a "symm" plan carry a 4th element: the mirror flag
# (1 = the leaf is stored at the mirrored (row, col) and must be read
# transposed — see plan_symm).
Term = Tuple[int, int, int]
# A destination is (dest_row_block, dest_col_block, sign).
Dest = Tuple[int, int, int]


@dataclass(frozen=True)
class Product:
    """One leaf product: (signed sum of A blocks)^T-or-not @ (signed sum)."""
    kind: str                 # "syrk" (diagonal gram leaf) | "mm" (matmul leaf)
    left: Tuple[Term, ...]
    right: Tuple[Term, ...]
    dests: Tuple[Dest, ...]


@dataclass(frozen=True)
class Contribution:
    """One (product, destination) pair — the unit the fused kernel executes."""
    di: int
    dj: int
    sign: int
    left: Tuple[Term, ...]
    right: Tuple[Term, ...]
    kind: str


# ---------------------------------------------------------------------------
# Per-level expansion tables: (a_quads, b_quads, dest_quads), each entry
# (row, col, sign) over the 2x2 quadrant grid of the operand / output.
# ---------------------------------------------------------------------------

# Strassen's 7 products, matching strassen.py (incl. the M7 sign erratum
# fix recorded in DESIGN.md §9: second operand of M7 is B21 + B22).
_STRASSEN = (
    # M1 = (A11 + A22)(B11 + B22) -> C11 + C22
    (((0, 0, 1), (1, 1, 1)), ((0, 0, 1), (1, 1, 1)), ((0, 0, 1), (1, 1, 1))),
    # M2 = (A21 + A22) B11 -> C21 - C22
    (((1, 0, 1), (1, 1, 1)), ((0, 0, 1),), ((1, 0, 1), (1, 1, -1))),
    # M3 = A11 (B12 - B22) -> C12 + C22
    (((0, 0, 1),), ((0, 1, 1), (1, 1, -1)), ((0, 1, 1), (1, 1, 1))),
    # M4 = A22 (B21 - B11) -> C11 + C21
    (((1, 1, 1),), ((1, 0, 1), (0, 0, -1)), ((0, 0, 1), (1, 0, 1))),
    # M5 = (A11 + A12) B22 -> -C11 + C12
    (((0, 0, 1), (0, 1, 1)), ((1, 1, 1),), ((0, 0, -1), (0, 1, 1))),
    # M6 = (A21 - A11)(B11 + B12) -> C22
    (((1, 0, 1), (0, 0, -1)), ((0, 0, 1), (0, 1, 1)), ((1, 1, 1),)),
    # M7 = (A12 - A22)(B21 + B22) -> C11
    (((0, 1, 1), (1, 1, -1)), ((1, 0, 1), (1, 1, 1)), ((0, 0, 1),)),
)

# Winograd's variant (7 mults / 15 adds), destinations expanded from the
# u-term recombination in strassen.py.
_WINOGRAD = (
    # M1 = A11 B11
    (((0, 0, 1),), ((0, 0, 1),),
     ((0, 0, 1), (0, 1, 1), (1, 0, 1), (1, 1, 1))),
    # M2 = A12 B21
    (((0, 1, 1),), ((1, 0, 1),), ((0, 0, 1),)),
    # M3 = (A11 + A12 - A21 - A22) B22
    (((0, 0, 1), (0, 1, 1), (1, 0, -1), (1, 1, -1)), ((1, 1, 1),),
     ((0, 1, 1),)),
    # M4 = A22 (B11 - B12 - B21 + B22)
    (((1, 1, 1),), ((0, 0, 1), (0, 1, -1), (1, 0, -1), (1, 1, 1)),
     ((1, 0, -1),)),
    # M5 = (A21 + A22)(B12 - B11)
    (((1, 0, 1), (1, 1, 1)), ((0, 1, 1), (0, 0, -1)),
     ((0, 1, 1), (1, 1, 1))),
    # M6 = (A21 + A22 - A11)(B11 + B22 - B12)
    (((1, 0, 1), (1, 1, 1), (0, 0, -1)), ((0, 0, 1), (1, 1, 1), (0, 1, -1)),
     ((0, 1, 1), (1, 0, 1), (1, 1, 1))),
    # M7 = (A11 - A21)(B22 - B12)
    (((0, 0, 1), (1, 0, -1)), ((1, 1, 1), (0, 1, -1)),
     ((1, 0, 1), (1, 1, 1))),
)

# Classical 2x2 block multiply in the same representation (8 products) —
# lets the planner/kernel serve variant="classical" with zero extra code.
_CLASSICAL = tuple(
    (((i, k, 1),), ((k, j, 1),), ((i, j, 1),))
    for i in (0, 1) for j in (0, 1) for k in (0, 1)
)

_VARIANTS = {"strassen": _STRASSEN, "winograd": _WINOGRAD,
             "classical": _CLASSICAL}


def _expand(level: int, left, right, dests, kind, transpose_left,
            table, out: List[Product]):
    """Recursively expand a block product ``level`` more times.

    ``transpose_left``: the left operand is conceptually ``X^t`` while terms
    are stored as blocks of ``X`` — quadrant (qi, qj) of ``X^t`` is block
    (qj, qi) of ``X``, so quadrant bits append swapped.
    """
    if level <= 0:
        out.append(Product(kind, tuple(left), tuple(right), tuple(dests)))
        return
    for a_quads, b_quads, d_quads in table:
        nl = []
        for qi, qj, s in a_quads:
            rb, cb = (qj, qi) if transpose_left else (qi, qj)
            nl.extend((r * 2 + rb, c * 2 + cb, s0 * s) for r, c, s0 in left)
        nr = []
        for qi, qj, s in b_quads:
            nr.extend((r * 2 + qi, c * 2 + qj, s0 * s) for r, c, s0 in right)
        nd = []
        for ci, cj, s in d_quads:
            nd.extend((di * 2 + ci, dj * 2 + cj, s0 * s)
                      for di, dj, s0 in dests)
        _expand(level - 1, nl, nr, nd, kind, transpose_left, table, out)


@dataclass(frozen=True)
class Plan:
    """A fully flattened schedule over a ``2^levels`` leaf-block grid."""
    kind: str                       # "ata" | "matmul"
    levels: int
    variant: str
    products: Tuple[Product, ...]

    @property
    def blocks(self) -> int:
        """Leaf blocks per matrix dimension."""
        return 1 << self.levels

    @property
    def max_terms(self) -> int:
        return max(max(len(p.left), len(p.right)) for p in self.products)

    @functools.lru_cache(maxsize=None)
    def contributions(self) -> Tuple[Contribution, ...]:
        """(product, destination) pairs, sorted by destination block."""
        out = [
            Contribution(di, dj, s, p.left, p.right, p.kind)
            for p in self.products for (di, dj, s) in p.dests
        ]
        out.sort(key=lambda c: (c.di, c.dj))
        return tuple(out)

    @functools.lru_cache(maxsize=None)
    def by_dest(self) -> Dict[Tuple[int, int], Tuple[Contribution, ...]]:
        grouped: Dict[Tuple[int, int], list] = {}
        for c in self.contributions():
            grouped.setdefault((c.di, c.dj), []).append(c)
        return {k: tuple(v) for k, v in grouped.items()}

    @property
    def max_contributions(self) -> int:
        return max(len(v) for v in self.by_dest().values())

    def mult_count(self, mb: int, nb: int, kb: int | None = None) -> int:
        """Scalar multiplications the plan performs with the given leaf
        shapes.  ATA plans: A leaves are (mb, nb), SYRK leaves compute only
        the lower triangle (paper's n(n+1)/2 saving).  Matmul plans: leaves
        (mb, kb) x (kb, nb).  Symm plans: X leaves (mb, nb) against square
        (nb, nb) leaves of the packed operand.  Matches
        ``cost_model.ata_mults_exact`` / ``strassen_mults_exact`` /
        ``symm_mults_exact`` evaluated with ``leaf=0`` at the padded shape
        (see tests/test_fused_ata.py, tests/test_properties.py).
        """
        total = 0
        for p in self.products:
            if p.kind == "syrk":
                total += mb * nb * (nb + 1) // 2
            elif self.kind == "ata":
                total += nb * mb * nb          # (nb, mb) @ (mb, nb)
            elif self.kind == "symm":
                total += mb * nb * nb          # (mb, nb) @ (nb, nb)
            else:
                total += mb * (kb if kb is not None else nb) * nb
        return total


@functools.lru_cache(maxsize=None)
def plan_ata(levels: int, variant: str = "strassen") -> Plan:
    """Flatten Algorithm 1 (ATA) into leaf products over a 2^levels grid.

    Recursion being flattened (paper Alg. 1 / ata.py):
      C11 = ATA(A11) + ATA(A21);  C22 = ATA(A12) + ATA(A22)
      C21 = HASA(A12^t, A11) + HASA(A22^t, A21)
    SYRK leaves land on diagonal destinations, HASA leaves strictly below
    the diagonal — all destinations satisfy di >= dj.
    """
    if levels < 0:
        raise ValueError(f"levels must be >= 0, got {levels}")
    table = _VARIANTS[variant]
    products: List[Product] = []

    def node(r: int, c: int, depth: int):
        if depth == levels:
            products.append(
                Product("syrk", ((r, c, 1),), ((r, c, 1),), ((c, c, 1),)))
            return
        for rb in (0, 1):
            for cb in (0, 1):
                node(r * 2 + rb, c * 2 + cb, depth + 1)
        # C21 of this node: HASA(A12^t, A11) + HASA(A22^t, A21), expanded
        # the remaining levels with the Strassen-variant table.  Left terms
        # are stored untransposed (blocks of A12/A22) — transpose_left
        # handles the quadrant mirroring, the kernel transposes tiles in
        # VMEM.
        for rb in (0, 1):
            _expand(levels - depth - 1,
                    [(r * 2 + rb, c * 2 + 1, 1)],
                    [(r * 2 + rb, c * 2 + 0, 1)],
                    [(c * 2 + 1, c * 2 + 0, 1)],
                    "mm", True, table, products)

    node(0, 0, 0)
    return Plan("ata", levels, variant, tuple(products))


@functools.lru_cache(maxsize=None)
def plan_matmul(levels: int, variant: str = "strassen") -> Plan:
    """Flatten (level-capped) Strassen C = A @ B into leaf products."""
    if levels < 0:
        raise ValueError(f"levels must be >= 0, got {levels}")
    products: List[Product] = []
    _expand(levels, [(0, 0, 1)], [(0, 0, 1)], [(0, 0, 1)], "mm", False,
            _VARIANTS[variant], products)
    return Plan("matmul", levels, variant, tuple(products))


@functools.lru_cache(maxsize=None)
def plan_symm(levels: int, variant: str = "strassen") -> Plan:
    """Flatten ``D = X @ Sym`` where ``Sym`` is *symmetric and stored only
    as its lower triangle* (packed blocks) into leaf products.

    This is the backward half of the paper's saving: the Gram VJP is
    ``dA = A (S + S^t)`` with a symmetric right operand, so the dense
    cotangent never needs to exist — every upper-triangle leaf read
    ``(i, j)``, i < j, becomes a mirrored ``(j, i)`` read of the stored
    lower triangle with the transpose folded into the executor's index
    maps.  Structurally the plan is a :func:`plan_matmul` flattening with
    the right-operand terms normalized to the lower triangle: each term is
    a 4-tuple ``(r, c, sign, mirrored)`` with ``r >= c`` always; mirrored
    terms (originally above the leaf diagonal) are read transposed.
    Diagonal leaves (``r == c``) straddle the stored triangle at *tile*
    granularity — the executor mirrors their upper tiles the same way at
    runtime (``kernels/strassen_fused.py``).
    """
    base = plan_matmul(levels, variant)
    products = tuple(
        Product("mm", p.left,
                tuple((r, c, s, 0) if r >= c else (c, r, s, 1)
                      for (r, c, s) in p.right),
                p.dests)
        for p in base.products)
    return Plan("symm", levels, variant, products)


# ---------------------------------------------------------------------------
# Dense reference evaluators (numpy) — oracle for the schedule itself,
# independent of the Pallas executor.
# ---------------------------------------------------------------------------

def _leaf(a: np.ndarray, r: int, c: int, blocks: int) -> np.ndarray:
    mb, nb = a.shape[0] // blocks, a.shape[1] // blocks
    return a[r * mb:(r + 1) * mb, c * nb:(c + 1) * nb]


def _gather(a: np.ndarray, terms, blocks: int) -> np.ndarray:
    out = None
    for r, c, s in terms:
        blk = s * _leaf(a, r, c, blocks)
        out = blk if out is None else out + blk
    return out


def evaluate_ata_plan(plan: Plan, a: np.ndarray) -> np.ndarray:
    """Execute an ATA plan densely with numpy: lower triangle of a^T a.

    ``a`` must be pre-padded to a multiple of ``plan.blocks`` in both dims.
    """
    B = plan.blocks
    m, n = a.shape
    assert m % B == 0 and n % B == 0, (a.shape, B)
    nb = n // B
    c = np.zeros((n, n), np.float64)
    af = np.asarray(a, np.float64)
    for p in plan.products:
        left = _gather(af, p.left, B)
        right = _gather(af, p.right, B)
        prod = left.T @ right
        for di, dj, s in p.dests:
            c[di * nb:(di + 1) * nb, dj * nb:(dj + 1) * nb] += s * prod
    return np.tril(c)


def evaluate_symm_plan(plan: Plan, x: np.ndarray,
                       sym_lower: np.ndarray) -> np.ndarray:
    """Execute a symm plan densely with numpy: ``x @ Sym`` where ``Sym``
    is the symmetric completion of ``sym_lower`` (an (n, n) array whose
    strict upper triangle is ignored — the evaluator provably never reads
    it, mirroring the executor's packed-storage contract).

    ``x`` is (m, n) pre-padded to ``plan.blocks`` multiples in both dims.
    """
    assert plan.kind == "symm", plan.kind
    B = plan.blocks
    m, n = x.shape
    assert n == sym_lower.shape[0] == sym_lower.shape[1], (x.shape,
                                                           sym_lower.shape)
    assert m % B == 0 and n % B == 0, (x.shape, B)
    mb, nb = m // B, n // B
    xf = np.asarray(x, np.float64)
    sl = np.tril(np.asarray(sym_lower, np.float64))  # upper never read
    out = np.zeros((m, n), np.float64)
    for p in plan.products:
        left = _gather(xf, p.left, B)
        right = None
        for r, c, s, mirrored in p.right:
            assert r >= c, "symm plan referenced the upper triangle"
            leaf = sl[r * nb:(r + 1) * nb, c * nb:(c + 1) * nb]
            if r == c:                       # rebuild the symmetric diagonal
                leaf = leaf + np.tril(leaf, -1).T
            blk = s * (leaf.T if mirrored else leaf)
            right = blk if right is None else right + blk
        prod = left @ right
        for di, dj, s in p.dests:
            out[di * mb:(di + 1) * mb, dj * nb:(dj + 1) * nb] += s * prod
    return out


def evaluate_matmul_plan(plan: Plan, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Execute a matmul plan densely with numpy: a @ b (pre-padded)."""
    B = plan.blocks
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and not (m % B or k % B or n % B), (a.shape, b.shape, B)
    mb, nb = m // B, n // B
    c = np.zeros((m, n), np.float64)
    af, bf = np.asarray(a, np.float64), np.asarray(b, np.float64)
    for p in plan.products:
        prod = _gather(af, p.left, B) @ _gather(bf, p.right, B)
        for di, dj, s in p.dests:
            c[di * mb:(di + 1) * mb, dj * nb:(dj + 1) * nb] += s * prod
    return c
