"""Leaf-task schedules — thin compatibility wrappers over the leaf IR.

The flattening machinery that used to live here (PR 1: hand-rolled ATA /
matmul expansion; PR 4: the symm variant) moved into ``core.leaf_ir`` as
``compile_program(kind, levels, variant)`` against the registered algebra
tables, together with aat (A A^t) and rank_k (C += A^t A) programs the
old per-kind planners could not express.  These wrappers keep the PR-1
``plan_*`` / ``evaluate_*`` names working for existing call sites and
tests; new code should target :mod:`repro.core.leaf_ir` directly.

``Plan`` is an alias of :class:`repro.core.leaf_ir.LeafProgram` (the IR
type is a compat superset: ``products`` / ``blocks`` / ``max_terms`` /
``contributions`` / ``by_dest`` / ``max_contributions`` / ``mult_count``
all keep their meanings).  Operand terms are uniformly 4-tuples
``(row, col, sign, trans)`` — the old 3-tuple ata/matmul terms gained a
trailing ``trans=0``.
"""
from __future__ import annotations

import numpy as np

from .leaf_ir import (
    Contribution, LeafOp, LeafProgram, compile_program, interpret_program,
)

# compat aliases — the IR types subsume the PR-1 dataclasses
Plan = LeafProgram
Product = LeafOp

__all__ = [
    "Product", "Contribution", "Plan",
    "plan_ata", "plan_matmul", "plan_symm",
    "evaluate_ata_plan", "evaluate_matmul_plan", "evaluate_symm_plan",
]


def plan_ata(levels: int, variant: str = "strassen") -> Plan:
    """Flatten Algorithm 1 (ATA) into leaf ops over a 2^levels grid."""
    return compile_program("ata", levels, variant)


def plan_matmul(levels: int, variant: str = "strassen") -> Plan:
    """Flatten (level-capped) Strassen C = A @ B into leaf ops."""
    return compile_program("matmul", levels, variant)


def plan_symm(levels: int, variant: str = "strassen") -> Plan:
    """Flatten ``D = X @ Sym`` (Sym symmetric, stored lower-tri only)."""
    return compile_program("symm", levels, variant)


def evaluate_ata_plan(plan: Plan, a: np.ndarray) -> np.ndarray:
    """Dense numpy execution of an ATA program: lower triangle of a^T a.

    ``a`` must be pre-padded to a multiple of ``plan.blocks`` in both dims.
    """
    return interpret_program(plan, a)


def evaluate_symm_plan(plan: Plan, x: np.ndarray,
                       sym_lower: np.ndarray) -> np.ndarray:
    """Dense numpy execution of a symm program: ``x @ Sym`` where ``Sym``
    is the symmetric completion of ``sym_lower`` (strict upper triangle
    provably never read — the packed-storage contract)."""
    assert plan.kind == "symm", plan.kind
    return interpret_program(plan, x, sym_lower)


def evaluate_matmul_plan(plan: Plan, a: np.ndarray,
                         b: np.ndarray) -> np.ndarray:
    """Dense numpy execution of a matmul program: a @ b (pre-padded)."""
    return interpret_program(plan, a, b)
