"""Normal equations via the paper's operator: solve min ||Ax - b|| through
A^tA x = A^t b with the Strassen-based gram (the paper's §1 motivating
application), then Cholesky on the packed symmetric result.

    PYTHONPATH=src python examples/least_squares.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ata_full


def main():
    key = jax.random.PRNGKey(0)
    m, n = 2048, 256
    a = jax.random.normal(key, (m, n), jnp.float32)
    x_true = jax.random.normal(jax.random.PRNGKey(1), (n,), jnp.float32)
    b = a @ x_true + 0.01 * jax.random.normal(jax.random.PRNGKey(2), (m,))

    @jax.jit
    def solve(a, b):
        gram = ata_full(a, levels=2, leaf=64)          # the paper's ATA
        rhs = a.T @ b
        # SPD solve (Cholesky) — gram is symmetric positive-definite
        chol = jnp.linalg.cholesky(gram + 1e-6 * jnp.eye(n))
        y = jax.scipy.linalg.solve_triangular(chol, rhs, lower=True)
        return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)

    x = solve(a, b)
    rel = float(jnp.linalg.norm(x - x_true) / jnp.linalg.norm(x_true))
    resid = float(jnp.linalg.norm(a @ x - b) / jnp.linalg.norm(b))
    print(f"x rel err {rel:.2e}; residual {resid:.2e}")
    # cross-check against the dense lstsq
    x_np, *_ = np.linalg.lstsq(np.asarray(a), np.asarray(b), rcond=None)
    print("vs numpy lstsq:", float(np.abs(x_np - np.asarray(x)).max()))
    assert rel < 1e-2
    print("OK")


if __name__ == "__main__":
    main()
