"""Serve a small model with batched requests through the KV-cache engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_params
from repro.runtime.serving import ServingEngine


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", num_layers=4,
                      d_model=256, num_heads=4, num_kv_heads=2, d_ff=512,
                      vocab_size=4096, head_dim=64)
    params = jax.jit(lambda k: init_params(cfg, k))(jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=4, max_seq=192, temperature=0.0)

    rng = np.random.default_rng(0)
    n_req = 12
    for i in range(n_req):
        plen = int(rng.integers(4, 48))
        eng.add_request(rng.integers(0, 4096, size=plen).tolist(),
                        max_new_tokens=24)
    t0 = time.perf_counter()
    finished = eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in finished)
    print(f"served {len(finished)}/{n_req} requests | {toks} tokens | "
          f"{dt:.2f}s | {toks/dt:.1f} tok/s (1 CPU core, 4 slots)")
    assert len(finished) == n_req
    print("OK")


if __name__ == "__main__":
    main()
