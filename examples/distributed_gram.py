"""The paper's ATA-P on an (emulated) 8-device mesh: all three distributed
schemes — paper-faithful all-reduce, reduce-scatter, and the beyond-paper
half-ring collective gram.

Run directly (it forces an 8-device host platform BEFORE importing jax):

    PYTHONPATH=src python examples/distributed_gram.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np                      # noqa: E402
import jax                              # noqa: E402
import jax.numpy as jnp                 # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import distributed_gram  # noqa: E402


def main():
    print("devices:", len(jax.devices()))
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (1024, 512), jnp.float32)
    ref = np.asarray(a).T @ np.asarray(a)

    from repro.launch.mesh import make_mesh
    mesh1 = make_mesh((8,), ("data",))
    a1 = jax.device_put(a, NamedSharding(mesh1, P("data", None)))
    for scheme in ("allreduce", "reducescatter"):
        c = distributed_gram(a1, mesh1, scheme=scheme, levels=2, leaf=64)
        err = np.abs(np.asarray(c) - ref).max() / np.abs(ref).max()
        print(f"{scheme:>14}: rel err {err:.2e}  (A row-sharded 8 ways; "
              f"one {'psum' if scheme == 'allreduce' else 'psum_scatter'} — "
              f"the paper's reduction tree)")

    mesh2 = make_mesh((2, 4), ("data", "model"))
    a2 = jax.device_put(a, NamedSharding(mesh2, P("data", "model")))
    c = distributed_gram(a2, mesh2, scheme="ring", row_axis="data",
                         col_axis="model", levels=1, leaf=64)
    err = np.abs(np.asarray(c) - ref).max() / np.abs(ref).max()
    print(f"{'half-ring':>14}: rel err {err:.2e}  (2x4 mesh; diagonal "
          f"blocks ATA, off-diagonal Strassen, floor(T/2) ppermute hops)")

    # 2.5D: replicate A over a 'rep' axis and deal the half-ring's
    # Strassen block tasks BFS-style across the replica groups —
    # ceil(floor(T/2)/c) sequential hops instead of floor(T/2).
    from repro.launch.mesh import make_gram_mesh
    mesh3 = make_gram_mesh(8, rep=2, ring=4)       # (rep=2, data=1, model=4)
    a3 = jax.device_put(a, NamedSharding(mesh3, P("data", "model")))
    c = distributed_gram(a3, mesh3, scheme="bfs25d", row_axis="data",
                         col_axis="model", rep_axis="rep", levels=1, leaf=64)
    err = np.abs(np.asarray(c) - ref).max() / np.abs(ref).max()
    print(f"{'bfs25d (2.5D)':>14}: rel err {err:.2e}  (2x1x4 mesh; 2 "
          f"replica groups, 1 skew + ceil(2/2)-1 hops each)")

    # auto: the comm cost model (core.cost_model.rank_gram_schemes) picks
    # the scheme from the shape and the mesh axes.
    from repro.core.cost_model import rank_gram_schemes
    ranked = rank_gram_schemes(a.shape[0], a.shape[1], rows=1, ring=4,
                               rep=2)
    c = distributed_gram(a3, mesh3, scheme="auto", row_axis="data",
                         col_axis="model", rep_axis="rep", levels=1, leaf=64)
    err = np.abs(np.asarray(c) - ref).max() / np.abs(ref).max()
    print(f"{'auto':>14}: rel err {err:.2e}  (model ranking: "
          f"{[r.scheme for r in ranked]})")
    print("OK")


if __name__ == "__main__":
    main()
