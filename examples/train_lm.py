"""End-to-end driver: train a ~100M-param LM for a few hundred steps on
the synthetic stream, with checkpointing and the ATA-powered Shampoo
optimizer available via --optimizer shampoo.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig
from repro.runtime.trainer import Trainer


def model_100m() -> ModelConfig:
    """~106M params: 10L x d640 x ff2560, 32k vocab (untied)."""
    return ModelConfig(
        name="repro-100m", family="dense", num_layers=10, d_model=640,
        num_heads=10, num_kv_heads=10, d_ff=2560, vocab_size=32000,
        head_dim=64, attn_chunk_q=512, attn_chunk_kv=512,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "shampoo"))
    ap.add_argument("--workdir", default="/tmp/repro_train_100m")
    args = ap.parse_args(argv)

    cfg = model_100m()
    n_params = cfg.param_count()
    print(f"model: {n_params/1e6:.1f}M params")
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=30,
                     total_steps=args.steps, optimizer=args.optimizer,
                     checkpoint_every=100, shampoo_block_size=256,
                     shampoo_precond_interval=20)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=0, noise=0.02)
    tr = Trainer(cfg, tc, dc, args.workdir)
    hist = tr.run(args.steps)
    losses = [h["loss"] for h in hist]
    k = max(len(losses) // 10, 1)
    print(f"loss: start {sum(losses[:k])/k:.3f} -> "
          f"end {sum(losses[-k:])/k:.3f} over {len(losses)} steps")
    assert sum(losses[-k:]) / k < sum(losses[:k]) / k, "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
