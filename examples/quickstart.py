"""Quickstart: the paper's ATA operator in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ata, ata_full, strassen_matmul, distributed_gram
from repro.core.symmetry import pack_tril, unpack_tril
from repro.core.cost_model import ata_mults_exact, classical_ata_mults
from repro.kernels import ops


def main():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (384, 256), jnp.float32)

    # 1. lower triangle of A^t A via the Strassen-based recursion (Alg. 1)
    c = jax.jit(lambda a: ata(a, levels=2, leaf=64))(a)
    ref = np.tril(np.asarray(a).T @ np.asarray(a))
    print("ata  max err:", np.abs(np.asarray(c) - ref).max())

    # 2. symmetric full product + packed n(n+1)/2 storage
    cf = ata_full(a, levels=2, leaf=64)
    packed = pack_tril(cf)
    print("packed words:", packed.size, "vs dense", cf.size,
          f"({packed.size/cf.size:.2%})")
    assert np.allclose(np.asarray(unpack_tril(packed, 256)),
                       np.asarray(cf), atol=1e-4)

    # 3. generalized (rectangular) Strassen — the paper's HASA subroutine
    b = jax.random.normal(key, (256, 192), jnp.float32)
    d = strassen_matmul(a.T, jnp.concatenate([a, a], 1)[:, :192],
                        levels=2, leaf=64)
    print("hasa shape:", d.shape)
    del b

    # 4. multiplication counts: Alg. 1 vs conventional (paper §3.1)
    for n in (1024, 4096):
        e, cl = ata_mults_exact(n, n), classical_ata_mults(n)
        print(f"n={n}: ATA mults {e:.2e} vs classical {cl:.2e} "
              f"({e/cl:.2f}x)")

    # 5. the Pallas SYRK kernel (lower-tri blocks only; interpret on CPU)
    ck = ops.syrk(a, bk=128, bn=128)
    print("pallas syrk max err:", np.abs(np.asarray(ck) - ref).max())

    # 6. distributed gram on whatever mesh this process has (1 device here;
    #    becomes the paper's ATA-P reduction tree on a pod)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    cg = distributed_gram(a, mesh, scheme="allreduce", levels=1)
    print("distributed gram max err:",
          np.abs(np.asarray(cg) - (ref + ref.T - np.diag(np.diag(ref)))).max())

    # 7. the row gram A A^t (Arrigoni-Massini 2021) — same operator,
    #    gram_of="rows"; the fused path never materializes A^t
    cr = ata(a, gram_of="rows", levels=2, leaf=64)
    ref_rows = np.tril(np.asarray(a) @ np.asarray(a).T)
    print("ata rows max err:", np.abs(np.asarray(cr) - ref_rows).max())

    # 8. streaming rank-k accumulation: C += A_i^t A_i chunk by chunk in
    #    the kernel's packed tile-stack state — no per-chunk delta buffer
    from repro.gram import stream
    s = stream.stack_init(256, block=128)
    for chunk in (a[:128], a[128:]):
        s = stream.stack_update(s, chunk, levels=1, block=128)
    cs = stream.stack_finalize(s, 256, symmetrize=False)
    print("rank-k stream max err:", np.abs(np.asarray(cs) - ref).max(),
          f"({int(s.rows)} rows streamed)")
    print("OK")


if __name__ == "__main__":
    main()
