"""Child: distributed-gram comm benchmark on an 8-device host platform.

Run by ``benchmarks.bench_distributed`` in a subprocess (XLA_FLAGS must be
set before jax initializes); writes ``BENCH_distributed.json``.

Per (shape x scheme): the cost model's closed-form per-device wire bytes
and message rounds (``core.cost_model.gram_comm_cost``) next to the
*measured* collective traffic of the actual compiled program — a
``roofline.hlo_census.collective_census`` over the post-SPMD HLO (real
instructions and shapes, the same ring wire model per op) — plus wall
clock.  The acceptance gates: (1) modeled vs measured volume agrees
within a small factor for every scheme, (2) the modeled allreduce-vs-ring
ranking flips between the tall-skinny and the wide shape, and the
measured volumes reproduce the flip (the cost-model crossover that makes
scheme="auto" trustworthy).
"""
import os
import sys

os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=8"

import numpy as np                                   # noqa: E402
import jax                                           # noqa: E402
import jax.numpy as jnp                              # noqa: E402
from jax.sharding import Mesh                        # noqa: E402

from repro.core import cost_model, distributed_gram  # noqa: E402
from repro.roofline.hlo_census import collective_census  # noqa: E402

from benchmarks.common import timeit, write_json     # noqa: E402

LEVELS, LEAF = 1, 64

# 8 devices: (mesh shape, axis names, distributed_gram kwargs, model axes)
SCHEMES = {
    "allreduce": ((8,), ("data",), {}, dict(rows=8)),
    "reducescatter": ((8,), ("data",), {}, dict(rows=8)),
    "ring": ((2, 4), ("data", "model"),
             dict(row_axis="data", col_axis="model"),
             dict(rows=2, ring=4)),
    "bfs25d": ((2, 1, 4), ("rep", "data", "model"),
               dict(row_axis="data", col_axis="model", rep_axis="rep"),
               dict(rows=1, ring=4, rep=2)),
}


def _mesh(shape, names):
    return Mesh(np.array(jax.devices()[:int(np.prod(shape))]).reshape(shape),
                names)


def _measure(scheme, m, n):
    mesh_shape, names, kw, axes = SCHEMES[scheme]
    mesh = _mesh(mesh_shape, names)
    modeled = cost_model.gram_comm_cost(scheme, m, n, dtype_bytes=4, **axes)

    def fn(a):
        return distributed_gram(a, mesh, scheme=scheme, levels=LEVELS,
                                leaf=LEAF, assemble=False
                                if scheme in ("ring", "bfs25d") else True,
                                **kw)
    spec = jax.ShapeDtypeStruct((m, n), jnp.float32)
    compiled = jax.jit(fn).lower(spec).compile()
    ops = collective_census(compiled.as_text())
    measured = sum(op.wire_bytes for op in ops)
    a = jax.device_put(jax.random.normal(jax.random.PRNGKey(0), (m, n),
                                         jnp.float32))
    wall = timeit(compiled, a, warmup=1, iters=3)
    return {
        "scheme": scheme, "m": m, "n": n,
        "mesh": dict(zip(names, mesh_shape)),
        "modeled_wire_bytes": modeled.wire_bytes,
        "modeled_messages": modeled.messages,
        "modeled_flops": modeled.flops,
        "devices": modeled.devices,
        "measured_wire_bytes": measured,
        "measured_collectives": [
            {"kind": op.kind, "bytes": op.wire_bytes,
             "group": op.group_size} for op in ops],
        "wall_s": wall,
    }


def main():
    assert len(jax.devices()) == 8, jax.devices()
    quick = "--quick" in sys.argv
    tall = (1024, 128) if quick else (4096, 256)
    wide = (128, 1024) if quick else (256, 2048)

    rows = []
    for m, n in (tall, wide):
        for scheme in SCHEMES:
            r = _measure(scheme, m, n)
            ratio = r["measured_wire_bytes"] / max(r["modeled_wire_bytes"],
                                                   1.0)
            r["measured_over_modeled"] = ratio
            rows.append(r)
            print(f"[distributed] {scheme:>13} {m}x{n}: modeled "
                  f"{r['modeled_wire_bytes']/1e6:7.3f} MB, measured "
                  f"{r['measured_wire_bytes']/1e6:7.3f} MB "
                  f"(x{ratio:4.2f}), {r['wall_s']*1e3:7.2f} ms")
            # (1) the model tracks the compiled program's collectives
            assert 0.3 < ratio < 3.0, (scheme, m, n, ratio)

    def get(shape, scheme):
        return next(r for r in rows
                    if (r["m"], r["n"]) == shape and r["scheme"] == scheme)

    # (2) the allreduce-vs-ring crossover: tall-skinny favors the row
    # reduction, wide favors the ring family — modeled AND measured.
    cross = {}
    for label, shape in (("tall", tall), ("wide", wide)):
        ar, ring = get(shape, "allreduce"), get(shape, "ring")
        cross[label] = {
            "shape": shape,
            "modeled_allreduce_minus_ring":
                ar["modeled_wire_bytes"] - ring["modeled_wire_bytes"],
            "measured_allreduce_minus_ring":
                ar["measured_wire_bytes"] - ring["measured_wire_bytes"],
        }
    modeled_flip = (cross["tall"]["modeled_allreduce_minus_ring"] < 0 <
                    cross["wide"]["modeled_allreduce_minus_ring"])
    measured_flip = (cross["tall"]["measured_allreduce_minus_ring"] < 0 <
                     cross["wide"]["measured_allreduce_minus_ring"])
    cross["modeled_flip"] = modeled_flip
    cross["measured_flip"] = measured_flip
    print(f"[distributed] crossover modeled_flip={modeled_flip} "
          f"measured_flip={measured_flip}")
    assert modeled_flip and measured_flip, cross

    # the auto scheme agrees with the measured winner per shape (volume)
    for label, shape in (("tall", tall), ("wide", wide)):
        by_measured = min((r for r in rows if (r["m"], r["n"]) == shape),
                          key=lambda r: r["measured_wire_bytes"])
        cross.setdefault("measured_winner", {})[label] = \
            by_measured["scheme"]

    path = write_json("BENCH_distributed.json",
                      {"rows": rows, "crossover": cross})
    print(f"[distributed] wrote {path}")
    print("ALL_OK")


if __name__ == "__main__":
    main()
