"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

fig5  exec time   (measured CPU + modeled cluster)    <- paper Fig 5
fig6  speed-up                                        <- paper Fig 6
fig7  efficiency                                      <- paper Fig 7
fig8  Karp-Flatt                                      <- paper Fig 8
s3.1  multiplication counts vs (2/7) n^log2(7)        <- paper §3.1
s5    communication model + comm fraction             <- paper §5/§6.3.2
roofline  3-term roofline over dry-run artifacts      <- brief §Roofline
ata   fused-pipeline trajectory -> BENCH_ata.json     <- DESIGN.md §4
grads fused backward trajectory -> BENCH_grads.json   <- DESIGN.md §11
gram_service  batched vs sequential serving -> BENCH_gram_service.json
                                                      <- DESIGN.md §10
distributed  modeled vs measured comm volume per scheme (8 fake devices)
                                   -> BENCH_distributed.json <- DESIGN.md §5

``--smoke`` runs the fast interpret-mode kernel test suite plus the
quick distributed comm and backward benchmarks instead of the full
benchmarks (CI smoke target: validates the fused Pallas pipeline — both
directions — and the comm cost model on CPU in a couple of minutes).
"""
import argparse
import subprocess
import sys
import time

from . import (bench_exec_time, bench_speedup, bench_efficiency,
               bench_karpflatt, bench_flops, bench_comm, bench_roofline,
               bench_ata, bench_grads, bench_gram_service,
               bench_distributed)

ALL = [
    ("fig5_exec_time", bench_exec_time.run),
    ("fig6_speedup", bench_speedup.run),
    ("fig7_efficiency", bench_efficiency.run),
    ("fig8_karpflatt", bench_karpflatt.run),
    ("s31_flops", bench_flops.run),
    ("s5_comm", bench_comm.run),
    ("roofline", bench_roofline.run),
    ("ata_fused", bench_ata.run),
    ("grads", bench_grads.run),
    ("gram_service", bench_gram_service.run),
    ("distributed", bench_distributed.run),
]

SMOKE_TESTS = ["tests/test_fused_ata.py", "tests/test_fused_grads.py",
               "tests/test_kernels.py",
               "tests/test_core_ata.py", "tests/test_gram_stream.py",
               "tests/test_gram_engine.py", "tests/test_comm_cost.py"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="run the interpret-mode kernel tests plus the "
                         "quick distributed comm benchmark and exit")
    args = ap.parse_args(argv)
    if args.smoke:
        # multidevice-marked tests are excluded (they pay a child
        # interpreter each and run in CI's dedicated multidevice job);
        # the quick distributed bench below is the multi-device signal
        rc = subprocess.call(
            [sys.executable, "-m", "pytest", "-q",
             "-m", "not multidevice", *SMOKE_TESTS])
        if rc == 0:
            bench_distributed.run(quick=True)
            bench_grads.run(quick=True)
        sys.exit(rc)
    failures = []
    for name, fn in ALL:
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} {'='*(60-len(name))}")
        t0 = time.perf_counter()
        try:
            fn(quick=args.quick)
            print(f"--- {name} ok in {time.perf_counter()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            import traceback
            traceback.print_exc()
    if failures:
        print("\nFAILED:", failures)
        sys.exit(1)
    print("\nALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
