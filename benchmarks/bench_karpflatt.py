"""Fig 8 — Karp-Flatt experimentally-determined serial fraction
e = (1/S - 1/P)/(1 - 1/P). Paper: small and decreasing."""
from __future__ import annotations

from repro.core.cost_model import simulate_metrics
from .common import write_json, PAPER


def run(quick: bool = False):
    out = {}
    for n in PAPER["ns"]:
        rows = simulate_metrics(n, PAPER["ps"])["rows"]
        out[str(n)] = rows
        kf = [r["karp_flatt"] for r in rows]
        print(f"[fig8] n={n}: " + " ".join(f"{v:.4f}" for v in kf))
        assert all(v < 0.15 for v in kf), "KF not small"
        # decreasing trend over complete-level points (6, 38, 250)
        kfm = {r["P"]: r["karp_flatt"] for r in rows}
        assert kfm[6] > kfm[38] > kfm[250], "KF not decreasing"
    write_json("fig8_karpflatt.json", out)
    return out


if __name__ == "__main__":
    run()
