"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time

import jax

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

PAPER = {
    "max_speedup": 64.28,           # Fig 6, n=10000, P=250
    "efficiency_p6": 0.66,          # Fig 7
    "efficiency_p250": 0.26,
    "ps": (6, 12, 18, 38, 76, 114, 250),
    "complete_ps": (6, 38, 250),
    "ns": (5000, 10000),
    "comm_fraction": (0.0014, 0.0046),
}


# Floors for gate-grade timing (ISSUE 10): a wall clock used in an
# acceptance key must be a best-of->=5 after >=2 warmups — one warmup
# and 2-3 reps was noisy enough to flip CI comparisons.
MIN_WARMUP = 2
MIN_TIMED_REPS = 5


def timeit(fn, *args, warmup=MIN_WARMUP, iters=MIN_TIMED_REPS):
    """Best-of-N wall clock (seconds).  ``warmup``/``iters`` are clamped
    up to the module floors so no call site can quietly reintroduce the
    noisy 1-warmup/2-rep timing."""
    return timeit_detail(fn, *args, warmup=warmup, iters=iters)["wall_s"]


def timeit_detail(fn, *args, warmup=MIN_WARMUP, iters=MIN_TIMED_REPS):
    """Like :func:`timeit` but returns the full measurement record:
    ``{"wall_s": min, "reps": N, "warmup": W, "all_s": [...]}`` so bench
    rows can state the basis of every number they carry."""
    warmup = max(int(warmup), MIN_WARMUP)
    iters = max(int(iters), MIN_TIMED_REPS)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return {"wall_s": min(times), "reps": iters, "warmup": warmup,
            "all_s": times}


def write_json(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
