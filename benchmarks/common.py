"""Shared benchmark helpers."""
from __future__ import annotations

import json
import os
import time

import jax

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")

PAPER = {
    "max_speedup": 64.28,           # Fig 6, n=10000, P=250
    "efficiency_p6": 0.66,          # Fig 7
    "efficiency_p250": 0.26,
    "ps": (6, 12, 18, 38, 76, 114, 250),
    "complete_ps": (6, 38, 250),
    "ns": (5000, 10000),
    "comm_fraction": (0.0014, 0.0046),
}


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return min(times)


def write_json(name: str, payload) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
