"""Gram service trajectory: batched bucket dispatch vs sequential calls.

Drives the same mixed-size request trace through ``gram.GramEngine``
(continuous batching: bucketed shapes, one vmapped executable per bucket)
and two sequential baselines, and emits ``BENCH_gram_service.json``:

* **cold / status quo** — per-request jit dispatch at each request's own
  exact shape, compiles included on both sides: what serving the trace
  with plain library calls costs.  The service's bucketing bounds its
  compiles by the bucket count while the status quo compiles per distinct
  shape — this is the ">= 2x sequential per-request dispatch" comparison.
* **warm / bucketed** — the hard-mode baseline: sequential dispatch at
  bucket shapes with a pre-warmed jit cache, vs the pre-warmed engine.
  Isolates the pure batching effect; on CPU (no batch parallelism, XLA
  reference recursion for both) slot padding makes this < 1x, on batch-
  parallel hardware it is where the 2x is expected.

The acceptance bound enforced in CI is the recompile count
(<= number of buckets); throughputs are recorded for the trajectory.

A **fault-rate sweep** rides the same trace (DESIGN.md §13): the engine
re-serves it with output guards + Freivalds probes on while
``runtime.faults`` injects NaN-poisoned outputs, finite silent
corruption and failing executables at 0 / 1% / 10% rates, recording
success rate, degraded fraction and the latency percentiles under each —
plus the guard overhead on the fault-free path (verify off vs finite vs
probed), which acceptance requires to be in the noise.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ata import ata
from repro.gram import GramEngine, bucket_shape
from repro.launch.gram_serve import make_trace
from repro.obs import trace as obs_trace
from repro.obs.drift import DriftDetector
from repro.runtime import faults
from .common import write_json

LEVELS = 1
MIN_BUCKET = 32


def _ata_fn(x):
    return ata(x, levels=LEVELS, mode="auto", out_dtype=jnp.float32)


def _sequential_warm(shapes, arrays):
    """Hard-mode baseline: per-request dispatch at bucket shapes, jit
    cache pre-warmed (steady state, compiles excluded)."""
    compiled = {}
    for m, n in shapes:
        key = bucket_shape(m, n, min_side=MIN_BUCKET)
        if key not in compiled:
            spec = jax.ShapeDtypeStruct(key, jnp.float32)
            compiled[key] = jax.jit(_ata_fn).lower(spec).compile()
    lat = []
    t0 = time.perf_counter()
    for (m, n), a in zip(shapes, arrays):
        M, N = bucket_shape(m, n, min_side=MIN_BUCKET)
        pad = np.zeros((M, N), np.float32)
        pad[:m, :n] = a
        t_req = time.perf_counter()
        jax.block_until_ready(compiled[(M, N)](jnp.asarray(pad)))
        lat.append(time.perf_counter() - t_req)
    wall = time.perf_counter() - t0
    return wall, len(compiled), lat


def _sequential_cold(shapes, arrays):
    """Status-quo baseline: plain per-request library calls, each request
    jit'd at its own exact shape, compiles included in the wall clock."""
    fn = jax.jit(_ata_fn)
    lat, distinct = [], set()
    t0 = time.perf_counter()
    for shape, a in zip(shapes, arrays):
        distinct.add(shape)
        t_req = time.perf_counter()
        jax.block_until_ready(fn(jnp.asarray(a)))
        lat.append(time.perf_counter() - t_req)
    wall = time.perf_counter() - t0
    return wall, len(distinct), lat


def _pct(lats, p):
    s = sorted(lats)
    return s[min(int(p * len(s)), len(s) - 1)] if s else None


def _fault_specs(rate):
    """The chaos mix of the acceptance trace: guard-visible NaN output
    poisoning, *finite* silent corruption (only the Freivalds probe sees
    it) and crashing executables, all at ``rate``."""
    if rate <= 0:
        return []
    return [
        faults.FaultSpec("poison_output", rate=rate),
        faults.FaultSpec("poison_output", rate=rate, value=3.0),
        faults.FaultSpec("exec_fail", rate=rate,
                         site="gram.engine.exec*"),
    ]


def _serve_trace(shapes, arrays, slots, *, verify, rate=0.0, seed=0):
    """One engine pass over the trace under a fault profile; returns
    (stats, wall_s, finished)."""
    eng = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET,
                     verify=verify, max_retries=4, breaker_threshold=2,
                     verify_seed=seed)
    eng.prewarm(shapes)
    for a in arrays:
        eng.submit(a, full=False)
    with faults.inject(*_fault_specs(rate), seed=seed):
        t0 = time.perf_counter()
        finished = eng.run_to_completion()
        wall = time.perf_counter() - t0
    return eng.stats(), wall, finished


def _fault_sweep(shapes, arrays, slots, requests):
    """Success rate / degraded fraction / latency percentiles under
    injected fault rates, plus the fault-free guard overhead."""
    sweep = {}
    for rate in (0.0, 0.01, 0.10):
        stats, wall, finished = _serve_trace(
            shapes, arrays, slots, verify=2, rate=rate, seed=17)
        ok = [r for r in finished if r.status == "ok"]
        nonfinite = sum(1 for r in ok if not np.isfinite(r.result).all())
        lat = [r.latency_s for r in finished if r.latency_s is not None]
        sweep[f"rate_{rate:g}"] = {
            "injected_rate": rate,
            "success_rate": len(ok) / requests,
            "degraded_fraction": stats["degraded_served"] / requests,
            "retries": stats["retries"],
            "guard_vetoes": stats["guard_failures"],
            "nonfinite_served": nonfinite,
            "wall_s": wall,
            "throughput_rps": requests / wall,
            "p50_latency_s": _pct(lat, 0.50),
            "p99_latency_s": _pct(lat, 0.99),
        }
        print(f"[gram_service] faults {rate:>4.0%}: "
              f"{len(ok)}/{requests} ok, "
              f"{stats['degraded_served']} degraded, "
              f"{stats['retries']} retries, "
              f"{stats['guard_failures']} guard vetoes, "
              f"p99 {sweep[f'rate_{rate:g}']['p99_latency_s']*1e3:.1f}ms")

    # guard overhead on the fault-free path: off vs finite scan vs probes
    # (best of 3 passes — single-pass walls here are a few ms and noisy)
    overhead = {}
    for name, verify in (("off", "off"), ("finite", "finite"),
                         ("probes_2", 2)):
        wall = min(_serve_trace(shapes, arrays, slots, verify=verify)[1]
                   for _ in range(3))
        overhead[name] = {"wall_s": wall,
                          "throughput_rps": requests / wall}
    base = overhead["off"]["wall_s"]
    for name in overhead:
        overhead[name]["overhead_vs_off"] = \
            overhead[name]["wall_s"] / base - 1.0
    print(f"[gram_service] guard overhead vs off: finite "
          f"{overhead['finite']['overhead_vs_off']:+.1%}, 2 probes "
          f"{overhead['probes_2']['overhead_vs_off']:+.1%}")
    return sweep, overhead


def _tracer_overhead(shapes, arrays, slots, requests):
    """Flight-recorder cost, two ways (DESIGN.md §14).

    A/B walls (tracer off vs enabled, best of 3) record what turning the
    recorder ON costs.  The acceptance bound is on the *disabled* path —
    but the disabled path IS the baseline path, so a wall-clock A/B of
    "off vs off" is pure noise; the honest bound is derived:
    (events/request x measured per-disabled-hook cost) over the
    per-request wall, which must stay < 2%.
    """
    obs_trace.set_tracer(None)
    wall_off = min(_serve_trace(shapes, arrays, slots, verify="finite")[1]
                   for _ in range(3))
    tracer = obs_trace.set_tracer(obs_trace.Tracer(enabled=True))
    try:
        walls_on = [_serve_trace(shapes, arrays, slots, verify="finite")[1]
                    for _ in range(3)]
    finally:
        obs_trace.set_tracer(None)
    hook_s = obs_trace.disabled_hook_cost()
    events_per_req = len(tracer.events()) / (3 * requests) \
        + tracer.dropped / (3 * requests)
    derived = hook_s * events_per_req / (wall_off / requests)
    out = {
        "wall_off_s": wall_off,
        "wall_on_s": min(walls_on),
        "enabled_overhead_vs_off": min(walls_on) / wall_off - 1.0,
        "events_per_request": events_per_req,
        "disabled_hook_cost_s": hook_s,
        "disabled_overhead_fraction": derived,
        "acceptance_disabled_overhead_lt_2pct": bool(derived < 0.02),
    }
    print(f"[gram_service] tracer: enabled {out['enabled_overhead_vs_off']:+.1%} "
          f"vs off; disabled path {derived:.4%} derived "
          f"({events_per_req:.1f} events/req x {hook_s*1e9:.0f}ns)")
    return out


def _drift_verdicts(eng):
    """Drift-detector verdicts: the live engine's wall-channel state from
    the warm pass, plus a deterministic falsified-fixture check — three
    synthetic buckets whose measured/predicted ratios share one machine
    constant except one bucket running 5x off its model; the detector
    must flag exactly that bucket."""
    det = DriftDetector(theta=2.0, min_samples=3)
    for _ in range(4):
        det.observe("64x64/float32/ata", measured=1.0, predicted=1e6,
                    channel="wall")
        det.observe("128x128/float32/ata", measured=4.0, predicted=4e6,
                    channel="wall")
        # falsified: model says 16e6 bytes, "machine" runs 5x slower
        # than that prediction implies
        det.observe("256x256/float32/ata", measured=80.0, predicted=16e6,
                    channel="wall")
    flagged = [str(k) for k in det.stale_keys("wall")]
    verdict = {
        "live": eng.drift.snapshot(),
        "synthetic_flagged": flagged,
        "acceptance_flags_only_falsified":
            flagged == ["256x256/float32/ata"],
    }
    print(f"[gram_service] drift: synthetic falsified bucket flagged="
          f"{flagged} (live findings: "
          f"{len(verdict['live']['findings'])})")
    return verdict


def run(quick: bool = False):
    requests = 16 if quick else 64
    slots = 4
    rng = np.random.default_rng(0)
    shapes = make_trace(rng, requests, 16, 128 if quick else 256)
    arrays = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    buckets = sorted({bucket_shape(m, n, min_side=MIN_BUCKET)
                      for m, n in shapes})

    # -- batched service, cold (the trace pays the bucket compiles) ---------
    eng = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET)
    for a in arrays:
        eng.submit(a, full=False)
    t0 = time.perf_counter()
    eng.run_to_completion()
    wall_cold = time.perf_counter() - t0
    stats = eng.stats()

    # -- batched service, warm (steady state) -------------------------------
    eng2 = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET)
    eng2.prewarm(shapes)
    for a in arrays:
        eng2.submit(a, full=False)
    t0 = time.perf_counter()
    eng2.run_to_completion()
    wall_warm = time.perf_counter() - t0
    warm_stats = eng2.stats()

    # -- sequential baselines -----------------------------------------------
    seq_cold_wall, seq_shapes, seq_cold_lat = _sequential_cold(shapes, arrays)
    seq_warm_wall, seq_buckets, seq_warm_lat = _sequential_warm(shapes,
                                                               arrays)

    # -- fault-rate sweep + guard overhead ----------------------------------
    fault_sweep, guard_overhead = _fault_sweep(shapes, arrays, slots,
                                               requests)

    # -- flight recorder: tracer overhead + drift verdicts ------------------
    tracer_overhead = _tracer_overhead(shapes, arrays, slots, requests)
    drift_verdicts = _drift_verdicts(eng2)

    speedup_cold = seq_cold_wall / wall_cold
    speedup_warm = seq_warm_wall / wall_warm
    ok_recompiles = stats["compile_count"] <= len(buckets)
    ok_faults = all(s["success_rate"] == 1.0 and s["nonfinite_served"] == 0
                    for s in fault_sweep.values())
    print(f"[gram_service] {requests} reqs, {len(buckets)} buckets "
          f"({seq_shapes} distinct shapes), backend={jax.default_backend()}")
    print(f"[gram_service] cold: service {wall_cold:.2f}s "
          f"({stats['compile_count']} compiles) vs per-shape dispatch "
          f"{seq_cold_wall:.2f}s ({seq_shapes} compiles) -> "
          f"{speedup_cold:.2f}x")
    print(f"[gram_service] warm: service {wall_warm:.2f}s vs bucketed "
          f"dispatch {seq_warm_wall:.2f}s -> {speedup_warm:.2f}x "
          f"(batching-only effect; expects batch-parallel hardware)")
    print(f"[gram_service] warm p50 {warm_stats['p50_latency_s']*1e3:.1f}ms "
          f"p99 {warm_stats['p99_latency_s']*1e3:.1f}ms; acceptance "
          f"recompiles<=buckets: {ok_recompiles}")

    payload = {
        "requests": requests,
        "slots": slots,
        "backend": jax.default_backend(),
        "buckets": [list(b) for b in buckets],
        "distinct_shapes": seq_shapes,
        "batched_cold": {
            "wall_s": wall_cold,
            "throughput_rps": requests / wall_cold,
            "p50_latency_s": stats["p50_latency_s"],
            "p99_latency_s": stats["p99_latency_s"],
            "recompile_count": stats["compile_count"],
            "ticks": stats["ticks"],
        },
        "batched_warm": {
            "wall_s": wall_warm,
            "throughput_rps": requests / wall_warm,
            "p50_latency_s": warm_stats["p50_latency_s"],
            "p99_latency_s": warm_stats["p99_latency_s"],
        },
        "sequential_cold_per_shape": {
            "wall_s": seq_cold_wall,
            "throughput_rps": requests / seq_cold_wall,
            "p50_latency_s": _pct(seq_cold_lat, 0.50),
            "p99_latency_s": _pct(seq_cold_lat, 0.99),
            "recompile_count": seq_shapes,
        },
        "sequential_warm_bucketed": {
            "wall_s": seq_warm_wall,
            "throughput_rps": requests / seq_warm_wall,
            "p50_latency_s": _pct(seq_warm_lat, 0.50),
            "p99_latency_s": _pct(seq_warm_lat, 0.99),
            "recompile_count": seq_buckets,
        },
        "fault_sweep": fault_sweep,
        "guard_overhead": guard_overhead,
        "tracer_overhead": tracer_overhead,
        "drift": drift_verdicts,
        "speedup_vs_status_quo": speedup_cold,
        "speedup_warm_batching_only": speedup_warm,
        "acceptance_recompiles_le_buckets": ok_recompiles,
        "acceptance_speedup_ge_2x": speedup_cold >= 2.0,
        "acceptance_faults_all_served": ok_faults,
        "acceptance_tracer_overhead_lt_2pct":
            tracer_overhead["acceptance_disabled_overhead_lt_2pct"],
        "acceptance_drift_flags_only_falsified":
            drift_verdicts["acceptance_flags_only_falsified"],
    }
    path = write_json("BENCH_gram_service.json", payload)
    print(f"[gram_service] wrote {path}")
    return payload


if __name__ == "__main__":
    run()
