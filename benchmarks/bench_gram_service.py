"""Gram service trajectory: batched bucket dispatch vs sequential calls.

Drives the same mixed-size request trace through ``gram.GramEngine``
(continuous batching: bucketed shapes, one vmapped executable per bucket)
and two sequential baselines, and emits ``BENCH_gram_service.json``:

* **cold / status quo** — per-request jit dispatch at each request's own
  exact shape, compiles included on both sides: what serving the trace
  with plain library calls costs.  The service's bucketing bounds its
  compiles by the bucket count while the status quo compiles per distinct
  shape — this is the ">= 2x sequential per-request dispatch" comparison.
* **warm / bucketed** — the hard-mode baseline: sequential dispatch at
  bucket shapes with a pre-warmed jit cache, vs the pre-warmed engine.
  Isolates the pure batching effect; on CPU (no batch parallelism, XLA
  reference recursion for both) slot padding makes this < 1x, on batch-
  parallel hardware it is where the 2x is expected.

The acceptance bound enforced in CI is the recompile count
(<= number of buckets); throughputs are recorded for the trajectory.

A **fault-rate sweep** rides the same trace (DESIGN.md §13): the engine
re-serves it with output guards + Freivalds probes on while
``runtime.faults`` injects NaN-poisoned outputs, finite silent
corruption and failing executables at 0 / 1% / 10% rates, recording
success rate, degraded fraction and the latency percentiles under each —
plus the guard overhead on the fault-free path (verify off vs finite vs
probed), which acceptance requires to be in the noise.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ata import ata
from repro.gram import GramEngine, bucket_shape
from repro.launch.gram_serve import make_trace
from repro.runtime import faults
from .common import write_json

LEVELS = 1
MIN_BUCKET = 32


def _ata_fn(x):
    return ata(x, levels=LEVELS, mode="auto", out_dtype=jnp.float32)


def _sequential_warm(shapes, arrays):
    """Hard-mode baseline: per-request dispatch at bucket shapes, jit
    cache pre-warmed (steady state, compiles excluded)."""
    compiled = {}
    for m, n in shapes:
        key = bucket_shape(m, n, min_side=MIN_BUCKET)
        if key not in compiled:
            spec = jax.ShapeDtypeStruct(key, jnp.float32)
            compiled[key] = jax.jit(_ata_fn).lower(spec).compile()
    lat = []
    t0 = time.perf_counter()
    for (m, n), a in zip(shapes, arrays):
        M, N = bucket_shape(m, n, min_side=MIN_BUCKET)
        pad = np.zeros((M, N), np.float32)
        pad[:m, :n] = a
        t_req = time.perf_counter()
        jax.block_until_ready(compiled[(M, N)](jnp.asarray(pad)))
        lat.append(time.perf_counter() - t_req)
    wall = time.perf_counter() - t0
    return wall, len(compiled), lat


def _sequential_cold(shapes, arrays):
    """Status-quo baseline: plain per-request library calls, each request
    jit'd at its own exact shape, compiles included in the wall clock."""
    fn = jax.jit(_ata_fn)
    lat, distinct = [], set()
    t0 = time.perf_counter()
    for shape, a in zip(shapes, arrays):
        distinct.add(shape)
        t_req = time.perf_counter()
        jax.block_until_ready(fn(jnp.asarray(a)))
        lat.append(time.perf_counter() - t_req)
    wall = time.perf_counter() - t0
    return wall, len(distinct), lat


def _pct(lats, p):
    s = sorted(lats)
    return s[min(int(p * len(s)), len(s) - 1)] if s else None


def _fault_specs(rate):
    """The chaos mix of the acceptance trace: guard-visible NaN output
    poisoning, *finite* silent corruption (only the Freivalds probe sees
    it) and crashing executables, all at ``rate``."""
    if rate <= 0:
        return []
    return [
        faults.FaultSpec("poison_output", rate=rate),
        faults.FaultSpec("poison_output", rate=rate, value=3.0),
        faults.FaultSpec("exec_fail", rate=rate,
                         site="gram.engine.exec*"),
    ]


def _serve_trace(shapes, arrays, slots, *, verify, rate=0.0, seed=0):
    """One engine pass over the trace under a fault profile; returns
    (stats, wall_s, finished)."""
    eng = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET,
                     verify=verify, max_retries=4, breaker_threshold=2,
                     verify_seed=seed)
    eng.prewarm(shapes)
    for a in arrays:
        eng.submit(a, full=False)
    with faults.inject(*_fault_specs(rate), seed=seed):
        t0 = time.perf_counter()
        finished = eng.run_to_completion()
        wall = time.perf_counter() - t0
    return eng.stats(), wall, finished


def _fault_sweep(shapes, arrays, slots, requests):
    """Success rate / degraded fraction / latency percentiles under
    injected fault rates, plus the fault-free guard overhead."""
    sweep = {}
    for rate in (0.0, 0.01, 0.10):
        stats, wall, finished = _serve_trace(
            shapes, arrays, slots, verify=2, rate=rate, seed=17)
        ok = [r for r in finished if r.status == "ok"]
        nonfinite = sum(1 for r in ok if not np.isfinite(r.result).all())
        lat = [r.latency_s for r in finished if r.latency_s is not None]
        sweep[f"rate_{rate:g}"] = {
            "injected_rate": rate,
            "success_rate": len(ok) / requests,
            "degraded_fraction": stats["degraded_served"] / requests,
            "retries": stats["retries"],
            "guard_vetoes": stats["guard_failures"],
            "nonfinite_served": nonfinite,
            "wall_s": wall,
            "throughput_rps": requests / wall,
            "p50_latency_s": _pct(lat, 0.50),
            "p99_latency_s": _pct(lat, 0.99),
        }
        print(f"[gram_service] faults {rate:>4.0%}: "
              f"{len(ok)}/{requests} ok, "
              f"{stats['degraded_served']} degraded, "
              f"{stats['retries']} retries, "
              f"{stats['guard_failures']} guard vetoes, "
              f"p99 {sweep[f'rate_{rate:g}']['p99_latency_s']*1e3:.1f}ms")

    # guard overhead on the fault-free path: off vs finite scan vs probes
    # (best of 3 passes — single-pass walls here are a few ms and noisy)
    overhead = {}
    for name, verify in (("off", "off"), ("finite", "finite"),
                         ("probes_2", 2)):
        wall = min(_serve_trace(shapes, arrays, slots, verify=verify)[1]
                   for _ in range(3))
        overhead[name] = {"wall_s": wall,
                          "throughput_rps": requests / wall}
    base = overhead["off"]["wall_s"]
    for name in overhead:
        overhead[name]["overhead_vs_off"] = \
            overhead[name]["wall_s"] / base - 1.0
    print(f"[gram_service] guard overhead vs off: finite "
          f"{overhead['finite']['overhead_vs_off']:+.1%}, 2 probes "
          f"{overhead['probes_2']['overhead_vs_off']:+.1%}")
    return sweep, overhead


def run(quick: bool = False):
    requests = 16 if quick else 64
    slots = 4
    rng = np.random.default_rng(0)
    shapes = make_trace(rng, requests, 16, 128 if quick else 256)
    arrays = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    buckets = sorted({bucket_shape(m, n, min_side=MIN_BUCKET)
                      for m, n in shapes})

    # -- batched service, cold (the trace pays the bucket compiles) ---------
    eng = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET)
    for a in arrays:
        eng.submit(a, full=False)
    t0 = time.perf_counter()
    eng.run_to_completion()
    wall_cold = time.perf_counter() - t0
    stats = eng.stats()

    # -- batched service, warm (steady state) -------------------------------
    eng2 = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET)
    eng2.prewarm(shapes)
    for a in arrays:
        eng2.submit(a, full=False)
    t0 = time.perf_counter()
    eng2.run_to_completion()
    wall_warm = time.perf_counter() - t0
    warm_stats = eng2.stats()

    # -- sequential baselines -----------------------------------------------
    seq_cold_wall, seq_shapes, seq_cold_lat = _sequential_cold(shapes, arrays)
    seq_warm_wall, seq_buckets, seq_warm_lat = _sequential_warm(shapes,
                                                               arrays)

    # -- fault-rate sweep + guard overhead ----------------------------------
    fault_sweep, guard_overhead = _fault_sweep(shapes, arrays, slots,
                                               requests)

    speedup_cold = seq_cold_wall / wall_cold
    speedup_warm = seq_warm_wall / wall_warm
    ok_recompiles = stats["compile_count"] <= len(buckets)
    ok_faults = all(s["success_rate"] == 1.0 and s["nonfinite_served"] == 0
                    for s in fault_sweep.values())
    print(f"[gram_service] {requests} reqs, {len(buckets)} buckets "
          f"({seq_shapes} distinct shapes), backend={jax.default_backend()}")
    print(f"[gram_service] cold: service {wall_cold:.2f}s "
          f"({stats['compile_count']} compiles) vs per-shape dispatch "
          f"{seq_cold_wall:.2f}s ({seq_shapes} compiles) -> "
          f"{speedup_cold:.2f}x")
    print(f"[gram_service] warm: service {wall_warm:.2f}s vs bucketed "
          f"dispatch {seq_warm_wall:.2f}s -> {speedup_warm:.2f}x "
          f"(batching-only effect; expects batch-parallel hardware)")
    print(f"[gram_service] warm p50 {warm_stats['p50_latency_s']*1e3:.1f}ms "
          f"p99 {warm_stats['p99_latency_s']*1e3:.1f}ms; acceptance "
          f"recompiles<=buckets: {ok_recompiles}")

    payload = {
        "requests": requests,
        "slots": slots,
        "backend": jax.default_backend(),
        "buckets": [list(b) for b in buckets],
        "distinct_shapes": seq_shapes,
        "batched_cold": {
            "wall_s": wall_cold,
            "throughput_rps": requests / wall_cold,
            "p50_latency_s": stats["p50_latency_s"],
            "p99_latency_s": stats["p99_latency_s"],
            "recompile_count": stats["compile_count"],
            "ticks": stats["ticks"],
        },
        "batched_warm": {
            "wall_s": wall_warm,
            "throughput_rps": requests / wall_warm,
            "p50_latency_s": warm_stats["p50_latency_s"],
            "p99_latency_s": warm_stats["p99_latency_s"],
        },
        "sequential_cold_per_shape": {
            "wall_s": seq_cold_wall,
            "throughput_rps": requests / seq_cold_wall,
            "p50_latency_s": _pct(seq_cold_lat, 0.50),
            "p99_latency_s": _pct(seq_cold_lat, 0.99),
            "recompile_count": seq_shapes,
        },
        "sequential_warm_bucketed": {
            "wall_s": seq_warm_wall,
            "throughput_rps": requests / seq_warm_wall,
            "p50_latency_s": _pct(seq_warm_lat, 0.50),
            "p99_latency_s": _pct(seq_warm_lat, 0.99),
            "recompile_count": seq_buckets,
        },
        "fault_sweep": fault_sweep,
        "guard_overhead": guard_overhead,
        "speedup_vs_status_quo": speedup_cold,
        "speedup_warm_batching_only": speedup_warm,
        "acceptance_recompiles_le_buckets": ok_recompiles,
        "acceptance_speedup_ge_2x": speedup_cold >= 2.0,
        "acceptance_faults_all_served": ok_faults,
    }
    path = write_json("BENCH_gram_service.json", payload)
    print(f"[gram_service] wrote {path}")
    return payload


if __name__ == "__main__":
    run()
