"""Gram service trajectory: batched bucket dispatch vs sequential calls.

Drives the same mixed-size request trace through ``gram.GramEngine``
(continuous batching: bucketed shapes, one vmapped executable per bucket)
and two sequential baselines, and emits ``BENCH_gram_service.json``:

* **cold / status quo** — per-request jit dispatch at each request's own
  exact shape, compiles included on both sides: what serving the trace
  with plain library calls costs.  The service's bucketing bounds its
  compiles by the bucket count while the status quo compiles per distinct
  shape — this is the ">= 2x sequential per-request dispatch" comparison.
* **warm / bucketed** — the hard-mode baseline: sequential dispatch at
  bucket shapes with a pre-warmed jit cache, vs the pre-warmed engine.
  Isolates the pure batching effect; on CPU (no batch parallelism, XLA
  reference recursion for both) slot padding makes this < 1x, on batch-
  parallel hardware it is where the 2x is expected.

The acceptance bound enforced in CI is the recompile count
(<= number of buckets); throughputs are recorded for the trajectory.

A **fault-rate sweep** rides the same trace (DESIGN.md §13): the engine
re-serves it with output guards + Freivalds probes on while
``runtime.faults`` injects NaN-poisoned outputs, finite silent
corruption and failing executables at 0 / 1% / 10% rates, recording
success rate, degraded fraction and the latency percentiles under each —
plus the guard overhead on the fault-free path (verify off vs finite vs
probed), which acceptance requires to be in the noise.

The **sustained-load section** (DESIGN.md §15) drives the async engine
open-loop: Poisson arrivals at sub-critical / critical / 2x-overload
rates against a *deterministic* service floor — an injected
``exec_delay`` makes every batch take ``DELAY`` seconds, so critical
capacity is ``slots / DELAY`` req/s on any machine and the offered rates
are machine-independent multiples of it.  Each phase records offered vs
admitted vs served rates, shed and deadline-miss fractions, queue peak
and per-tenant latency percentiles.  Acceptance: the engine stays live
under 2x overload (queue bounded, every request terminal, sheds fail
fast), admitted-and-served requests meet their deadlines, and a
compliant tenant's p99 is insensitive to a neighboring tenant turning
into an abusive flood (bounded change, and the compliant tenant is not
the one being shed).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ata import ata
from repro.gram import GramEngine, bucket_shape
from repro.launch.gram_serve import make_trace
from repro.obs import trace as obs_trace
from repro.obs.drift import DriftDetector
from repro.runtime import faults
from .common import write_json

LEVELS = 1
MIN_BUCKET = 32


def _ata_fn(x):
    return ata(x, levels=LEVELS, mode="auto", out_dtype=jnp.float32)


def _sequential_warm(shapes, arrays):
    """Hard-mode baseline: per-request dispatch at bucket shapes, jit
    cache pre-warmed (steady state, compiles excluded)."""
    compiled = {}
    for m, n in shapes:
        key = bucket_shape(m, n, min_side=MIN_BUCKET)
        if key not in compiled:
            spec = jax.ShapeDtypeStruct(key, jnp.float32)
            compiled[key] = jax.jit(_ata_fn).lower(spec).compile()
    lat = []
    t0 = time.perf_counter()
    for (m, n), a in zip(shapes, arrays):
        M, N = bucket_shape(m, n, min_side=MIN_BUCKET)
        pad = np.zeros((M, N), np.float32)
        pad[:m, :n] = a
        t_req = time.perf_counter()
        jax.block_until_ready(compiled[(M, N)](jnp.asarray(pad)))
        lat.append(time.perf_counter() - t_req)
    wall = time.perf_counter() - t0
    return wall, len(compiled), lat


def _sequential_cold(shapes, arrays):
    """Status-quo baseline: plain per-request library calls, each request
    jit'd at its own exact shape, compiles included in the wall clock."""
    fn = jax.jit(_ata_fn)
    lat, distinct = [], set()
    t0 = time.perf_counter()
    for shape, a in zip(shapes, arrays):
        distinct.add(shape)
        t_req = time.perf_counter()
        jax.block_until_ready(fn(jnp.asarray(a)))
        lat.append(time.perf_counter() - t_req)
    wall = time.perf_counter() - t0
    return wall, len(distinct), lat


def _pct(lats, p):
    s = sorted(lats)
    return s[min(int(p * len(s)), len(s) - 1)] if s else None


def _fault_specs(rate):
    """The chaos mix of the acceptance trace: guard-visible NaN output
    poisoning, *finite* silent corruption (only the Freivalds probe sees
    it) and crashing executables, all at ``rate``."""
    if rate <= 0:
        return []
    return [
        faults.FaultSpec("poison_output", rate=rate),
        faults.FaultSpec("poison_output", rate=rate, value=3.0),
        faults.FaultSpec("exec_fail", rate=rate,
                         site="gram.engine.exec*"),
    ]


def _serve_trace(shapes, arrays, slots, *, verify, rate=0.0, seed=0):
    """One engine pass over the trace under a fault profile; returns
    (stats, wall_s, finished)."""
    eng = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET,
                     verify=verify, max_retries=4, breaker_threshold=2,
                     verify_seed=seed)
    eng.prewarm(shapes)
    for a in arrays:
        eng.submit(a, full=False)
    with faults.inject(*_fault_specs(rate), seed=seed):
        t0 = time.perf_counter()
        finished = eng.run_to_completion()
        wall = time.perf_counter() - t0
    return eng.stats(), wall, finished


def _fault_sweep(shapes, arrays, slots, requests):
    """Success rate / degraded fraction / latency percentiles under
    injected fault rates, plus the fault-free guard overhead."""
    sweep = {}
    for rate in (0.0, 0.01, 0.10):
        stats, wall, finished = _serve_trace(
            shapes, arrays, slots, verify=2, rate=rate, seed=17)
        ok = [r for r in finished if r.status == "ok"]
        nonfinite = sum(1 for r in ok if not np.isfinite(r.result).all())
        lat = [r.latency_s for r in finished if r.latency_s is not None]
        sweep[f"rate_{rate:g}"] = {
            "injected_rate": rate,
            "success_rate": len(ok) / requests,
            "degraded_fraction": stats["degraded_served"] / requests,
            "retries": stats["retries"],
            "guard_vetoes": stats["guard_failures"],
            "nonfinite_served": nonfinite,
            "wall_s": wall,
            "throughput_rps": requests / wall,
            "p50_latency_s": _pct(lat, 0.50),
            "p99_latency_s": _pct(lat, 0.99),
        }
        print(f"[gram_service] faults {rate:>4.0%}: "
              f"{len(ok)}/{requests} ok, "
              f"{stats['degraded_served']} degraded, "
              f"{stats['retries']} retries, "
              f"{stats['guard_failures']} guard vetoes, "
              f"p99 {sweep[f'rate_{rate:g}']['p99_latency_s']*1e3:.1f}ms")

    # guard overhead on the fault-free path: off vs finite scan vs probes
    # (best of 3 passes — single-pass walls here are a few ms and noisy)
    overhead = {}
    for name, verify in (("off", "off"), ("finite", "finite"),
                         ("probes_2", 2)):
        wall = min(_serve_trace(shapes, arrays, slots, verify=verify)[1]
                   for _ in range(3))
        overhead[name] = {"wall_s": wall,
                          "throughput_rps": requests / wall}
    base = overhead["off"]["wall_s"]
    for name in overhead:
        overhead[name]["overhead_vs_off"] = \
            overhead[name]["wall_s"] / base - 1.0
    print(f"[gram_service] guard overhead vs off: finite "
          f"{overhead['finite']['overhead_vs_off']:+.1%}, 2 probes "
          f"{overhead['probes_2']['overhead_vs_off']:+.1%}")
    return sweep, overhead


def _tracer_overhead(shapes, arrays, slots, requests):
    """Flight-recorder cost, two ways (DESIGN.md §14).

    A/B walls (tracer off vs enabled, best of 3) record what turning the
    recorder ON costs.  The acceptance bound is on the *disabled* path —
    but the disabled path IS the baseline path, so a wall-clock A/B of
    "off vs off" is pure noise; the honest bound is derived:
    (events/request x measured per-disabled-hook cost) over the
    per-request wall, which must stay < 2%.
    """
    obs_trace.set_tracer(None)
    wall_off = min(_serve_trace(shapes, arrays, slots, verify="finite")[1]
                   for _ in range(3))
    tracer = obs_trace.set_tracer(obs_trace.Tracer(enabled=True))
    try:
        walls_on = [_serve_trace(shapes, arrays, slots, verify="finite")[1]
                    for _ in range(3)]
    finally:
        obs_trace.set_tracer(None)
    hook_s = obs_trace.disabled_hook_cost()
    events_per_req = len(tracer.events()) / (3 * requests) \
        + tracer.dropped / (3 * requests)
    derived = hook_s * events_per_req / (wall_off / requests)
    out = {
        "wall_off_s": wall_off,
        "wall_on_s": min(walls_on),
        "enabled_overhead_vs_off": min(walls_on) / wall_off - 1.0,
        "events_per_request": events_per_req,
        "disabled_hook_cost_s": hook_s,
        "disabled_overhead_fraction": derived,
        "acceptance_disabled_overhead_lt_2pct": bool(derived < 0.02),
    }
    print(f"[gram_service] tracer: enabled {out['enabled_overhead_vs_off']:+.1%} "
          f"vs off; disabled path {derived:.4%} derived "
          f"({events_per_req:.1f} events/req x {hook_s*1e9:.0f}ns)")
    return out


def _drift_verdicts(eng):
    """Drift-detector verdicts: the live engine's wall-channel state from
    the warm pass, plus a deterministic falsified-fixture check — three
    synthetic buckets whose measured/predicted ratios share one machine
    constant except one bucket running 5x off its model; the detector
    must flag exactly that bucket."""
    det = DriftDetector(theta=2.0, min_samples=3)
    for _ in range(4):
        det.observe("64x64/float32/ata", measured=1.0, predicted=1e6,
                    channel="wall")
        det.observe("128x128/float32/ata", measured=4.0, predicted=4e6,
                    channel="wall")
        # falsified: model says 16e6 bytes, "machine" runs 5x slower
        # than that prediction implies
        det.observe("256x256/float32/ata", measured=80.0, predicted=16e6,
                    channel="wall")
    flagged = [str(k) for k in det.stale_keys("wall")]
    verdict = {
        "live": eng.drift.snapshot(),
        "synthetic_flagged": flagged,
        "acceptance_flags_only_falsified":
            flagged == ["256x256/float32/ata"],
    }
    print(f"[gram_service] drift: synthetic falsified bucket flagged="
          f"{flagged} (live findings: "
          f"{len(verdict['live']['findings'])})")
    return verdict


# -- sustained load: open-loop Poisson arrivals vs a deterministic floor --

DELAY = 0.02          # injected per-batch service time (exec_delay)
SLOTS_LOAD = 4        # batch slots in the load phases
DEADLINE_S = 0.35     # per-request SLO in the load phases
MAX_QUEUE = 48        # global admission bound in the load phases


def _poisson_arrivals(rng, rate, duration):
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= duration:
            return out
        out.append(t)


def _open_loop_phase(name, tenants, *, duration, seed):
    """One open-loop phase: merged Poisson arrival schedules (one per
    tenant, each with its own rate and shape) submitted on the wall
    clock regardless of completions, under an ``exec_delay`` service
    floor.  Returns the phase record."""
    rng = np.random.default_rng(seed)
    sched = []
    for tname, (rate, shape, gram_of) in tenants.items():
        sched += [(t, tname, shape, gram_of)
                  for t in _poisson_arrivals(rng, rate, duration)]
    sched.sort()
    shapes = sorted({shape for _, (_, shape, _) in tenants.items()})
    arrays = {s: rng.standard_normal(s).astype(np.float32) for s in shapes}
    eng = GramEngine(slots=SLOTS_LOAD, levels=0, min_bucket=16,
                     verify="finite", max_retries=2, backoff_s=0.0,
                     max_queue=MAX_QUEUE, tenant_quota=20,
                     tenant_max_inflight=SLOTS_LOAD - 1
                     if len(tenants) > 1 else None)
    for _, (_, shape, gram_of) in tenants.items():
        eng.serve(arrays[shape], full=False, gram_of=gram_of)
    futs = []                         # compiles stay out of the clock
    with faults.inject(faults.FaultSpec("exec_delay", delay=DELAY,
                                        site="gram.engine.exec.*")):
        eng.start()
        t0 = time.perf_counter()
        for t_arr, tname, shape, gram_of in sched:
            wait = t_arr - (time.perf_counter() - t0)
            if wait > 0:
                time.sleep(wait)
            futs.append((tname, eng.submit(arrays[shape], full=False,
                                           gram_of=gram_of,
                                           deadline_s=DEADLINE_S,
                                           tenant=tname)))
        drained = eng.drain(timeout=60.0)
        wall = time.perf_counter() - t0
        eng.shutdown()
    s = eng.stats()

    per_tenant = {}
    on_time = served = late = 0
    shed_lat = []
    for tname in tenants:
        mine = [f for tn, f in futs if tn == tname]
        ok = [f.request for f in mine if f.request.status == "ok"]
        lat = sorted(r.latency_s for r in ok)
        n_shed = sum(1 for f in mine if f.request.status == "shed")
        shed_lat += [f.request.latency_s for f in mine
                     if f.request.status == "shed"
                     and f.request.latency_s is not None]
        served += len(ok)
        for r in ok:
            # grace of one service quantum: a request that entered the
            # batch before its deadline finishes at most DELAY past it
            if r.t_deadline is None or r.t_done <= r.t_deadline + DELAY:
                on_time += 1
            else:
                late += 1
        per_tenant[tname] = {
            "offered": len(mine),
            "served": len(ok),
            "shed": n_shed,
            "failed": sum(1 for f in mine
                          if f.request.status == "failed"),
            "shed_fraction": n_shed / max(len(mine), 1),
            "p50_latency_s": _pct(lat, 0.50),
            "p99_latency_s": _pct(lat, 0.99),
        }
    rec = {
        "offered": len(futs),
        "offered_rps": len(futs) / duration,
        "capacity_rps": SLOTS_LOAD / DELAY,
        "duration_s": duration,
        "wall_s": wall,
        "drained": bool(drained),
        "all_terminal": all(f.done() for _, f in futs),
        "served": served,
        "served_rps": served / wall,
        "shed": s["shed"],
        "shed_fraction": s["shed"] / max(len(futs), 1),
        "deadline_missed": s["deadline_missed"],
        "served_on_time_fraction": on_time / max(served, 1),
        "served_late": late,
        "queue_peak": s["queue_peak"],
        "shed_p99_latency_s": _pct(sorted(shed_lat), 0.99),
        "ring": s["ring"],
        "tenants": per_tenant,
    }
    print(f"[gram_service] load/{name}: offered {rec['offered_rps']:.0f} "
          f"rps vs capacity {rec['capacity_rps']:.0f}, served {served}, "
          f"shed {s['shed']} ({rec['shed_fraction']:.0%}), on-time "
          f"{rec['served_on_time_fraction']:.1%}, queue_peak "
          f"{s['queue_peak']}")
    return rec


def _sustained_load(quick):
    """Sub-critical / critical / 2x-overload open-loop phases plus the
    fairness A/B: the compliant tenant keeps its offered rate while the
    neighbor turns from compliant into a 1.55x-capacity flood."""
    duration = 0.8 if quick else 2.0
    cap = SLOTS_LOAD / DELAY
    # same shape, different gram_of -> distinct buckets (so WFQ
    # arbitrates across them) with IDENTICAL per-request work, so the
    # vtime a request charges its tenant is the same on both sides and
    # the A/B isolates scheduling, not the cost model
    good_req = ((16, 16), "rows")
    peer_req = ((16, 16), "cols")
    # the compliant tenant keeps 0.35x capacity throughout; only the
    # neighbor changes character (0.65x compliant -> 1.65x flood), so
    # the phase totals hit 1.0x and 2.0x while "good" is identical
    phases = {
        "subcritical": _open_loop_phase(
            "subcritical", {"good": (0.5 * cap, *good_req)},
            duration=duration, seed=11),
        "critical": _open_loop_phase(
            "critical", {"good": (0.35 * cap, *good_req),
                         "peer": (0.65 * cap, *peer_req)},
            duration=duration, seed=12),
        "overload_2x": _open_loop_phase(
            "overload_2x", {"good": (0.35 * cap, *good_req),
                            "abuser": (1.65 * cap, *peer_req)},
            duration=duration, seed=13),
    }
    over = phases["overload_2x"]
    good_crit = phases["critical"]["tenants"]["good"]
    good_over = over["tenants"]["good"]
    p99_c, p99_o = good_crit["p99_latency_s"], good_over["p99_latency_s"]
    # relative bound with an absolute slack of a few service quanta:
    # scheduling granularity is one DELAY batch, CI walls are noisy
    fair_p99 = (p99_o is not None and p99_c is not None
                and p99_o <= p99_c * 1.2 + 6 * DELAY)
    fair_shed = good_over["shed_fraction"] < 0.05
    live = (over["drained"] and over["all_terminal"]
            and over["queue_peak"] <= MAX_QUEUE
            and (over["shed_p99_latency_s"] is None
                 or over["shed_p99_latency_s"] < 0.05))
    deadlines = min(p["served_on_time_fraction"]
                    for p in phases.values()) >= 0.99
    acceptance = {
        "acceptance_overload_live": bool(live),
        "acceptance_admitted_deadlines_met": bool(deadlines),
        "acceptance_tenant_fairness": bool(fair_p99 and fair_shed),
    }
    print(f"[gram_service] fairness: good p99 {p99_c*1e3:.1f}ms "
          f"(compliant neighbor) -> {p99_o*1e3:.1f}ms (abusive flood), "
          f"good shed {good_over['shed_fraction']:.1%}; "
          f"abuser shed {over['tenants']['abuser']['shed_fraction']:.1%}"
          if p99_c is not None and p99_o is not None else
          "[gram_service] fairness: good tenant starved (no p99)")
    print(f"[gram_service] sustained-load acceptance: {acceptance}")
    return phases, acceptance


def run(quick: bool = False):
    requests = 16 if quick else 64
    slots = 4
    rng = np.random.default_rng(0)
    shapes = make_trace(rng, requests, 16, 128 if quick else 256)
    arrays = [rng.standard_normal(s).astype(np.float32) for s in shapes]
    buckets = sorted({bucket_shape(m, n, min_side=MIN_BUCKET)
                      for m, n in shapes})

    # -- batched service, cold (the trace pays the bucket compiles) ---------
    eng = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET)
    for a in arrays:
        eng.submit(a, full=False)
    t0 = time.perf_counter()
    eng.run_to_completion()
    wall_cold = time.perf_counter() - t0
    stats = eng.stats()

    # -- batched service, warm (steady state) -------------------------------
    eng2 = GramEngine(slots=slots, levels=LEVELS, min_bucket=MIN_BUCKET)
    eng2.prewarm(shapes)
    for a in arrays:
        eng2.submit(a, full=False)
    t0 = time.perf_counter()
    eng2.run_to_completion()
    wall_warm = time.perf_counter() - t0
    warm_stats = eng2.stats()

    # -- sequential baselines -----------------------------------------------
    seq_cold_wall, seq_shapes, seq_cold_lat = _sequential_cold(shapes, arrays)
    seq_warm_wall, seq_buckets, seq_warm_lat = _sequential_warm(shapes,
                                                               arrays)

    # -- fault-rate sweep + guard overhead ----------------------------------
    fault_sweep, guard_overhead = _fault_sweep(shapes, arrays, slots,
                                               requests)

    # -- flight recorder: tracer overhead + drift verdicts ------------------
    tracer_overhead = _tracer_overhead(shapes, arrays, slots, requests)
    drift_verdicts = _drift_verdicts(eng2)

    # -- sustained load: open-loop Poisson phases (DESIGN.md §15) -----------
    load_phases, load_acceptance = _sustained_load(quick)

    speedup_cold = seq_cold_wall / wall_cold
    speedup_warm = seq_warm_wall / wall_warm
    ok_recompiles = stats["compile_count"] <= len(buckets)
    ok_faults = all(s["success_rate"] == 1.0 and s["nonfinite_served"] == 0
                    for s in fault_sweep.values())
    print(f"[gram_service] {requests} reqs, {len(buckets)} buckets "
          f"({seq_shapes} distinct shapes), backend={jax.default_backend()}")
    print(f"[gram_service] cold: service {wall_cold:.2f}s "
          f"({stats['compile_count']} compiles) vs per-shape dispatch "
          f"{seq_cold_wall:.2f}s ({seq_shapes} compiles) -> "
          f"{speedup_cold:.2f}x")
    print(f"[gram_service] warm: service {wall_warm:.2f}s vs bucketed "
          f"dispatch {seq_warm_wall:.2f}s -> {speedup_warm:.2f}x "
          f"(batching-only effect; expects batch-parallel hardware)")
    print(f"[gram_service] warm p50 {warm_stats['p50_latency_s']*1e3:.1f}ms "
          f"p99 {warm_stats['p99_latency_s']*1e3:.1f}ms; acceptance "
          f"recompiles<=buckets: {ok_recompiles}")

    payload = {
        "requests": requests,
        "slots": slots,
        "backend": jax.default_backend(),
        "buckets": [list(b) for b in buckets],
        "distinct_shapes": seq_shapes,
        "batched_cold": {
            "wall_s": wall_cold,
            "throughput_rps": requests / wall_cold,
            "p50_latency_s": stats["p50_latency_s"],
            "p99_latency_s": stats["p99_latency_s"],
            "recompile_count": stats["compile_count"],
            "ticks": stats["ticks"],
        },
        "batched_warm": {
            "wall_s": wall_warm,
            "throughput_rps": requests / wall_warm,
            "p50_latency_s": warm_stats["p50_latency_s"],
            "p99_latency_s": warm_stats["p99_latency_s"],
        },
        "sequential_cold_per_shape": {
            "wall_s": seq_cold_wall,
            "throughput_rps": requests / seq_cold_wall,
            "p50_latency_s": _pct(seq_cold_lat, 0.50),
            "p99_latency_s": _pct(seq_cold_lat, 0.99),
            "recompile_count": seq_shapes,
        },
        "sequential_warm_bucketed": {
            "wall_s": seq_warm_wall,
            "throughput_rps": requests / seq_warm_wall,
            "p50_latency_s": _pct(seq_warm_lat, 0.50),
            "p99_latency_s": _pct(seq_warm_lat, 0.99),
            "recompile_count": seq_buckets,
        },
        "fault_sweep": fault_sweep,
        "guard_overhead": guard_overhead,
        "tracer_overhead": tracer_overhead,
        "drift": drift_verdicts,
        "sustained_load": load_phases,
        "speedup_vs_status_quo": speedup_cold,
        "speedup_warm_batching_only": speedup_warm,
        "acceptance_recompiles_le_buckets": ok_recompiles,
        "acceptance_speedup_ge_2x": speedup_cold >= 2.0,
        "acceptance_faults_all_served": ok_faults,
        "acceptance_tracer_overhead_lt_2pct":
            tracer_overhead["acceptance_disabled_overhead_lt_2pct"],
        "acceptance_drift_flags_only_falsified":
            drift_verdicts["acceptance_flags_only_falsified"],
        **load_acceptance,
    }
    path = write_json("BENCH_gram_service.json", payload)
    print(f"[gram_service] wrote {path}")
    return payload


if __name__ == "__main__":
    run()
