"""§Roofline — three-term roofline table for every dry-run artifact
(arch x shape x mesh + the paper's gram cells)."""
from __future__ import annotations

import os

from repro.roofline.analysis import (load_artifacts, roofline_terms,
                                     render_table)
from .common import write_json

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(quick: bool = False):
    arts = load_artifacts(ART)
    if not arts:
        print("[roofline] no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    rows = [roofline_terms(a) for a in arts if a.get("status") == "ok"]
    rows.sort(key=lambda r: (r.get("kind") != "gram", r.get("cell", "")))
    base = [r for r in rows if "__flash" not in r["cell"]]
    opt = [r for r in rows if "__flash" in r["cell"]]

    print("--- BASELINE (paper-faithful XLA attention) " + "-" * 40)
    print(render_table(base))
    doms = {}
    for r in base:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"[roofline] {len(base)} baseline cells; dominant terms: {doms}")

    if opt:
        print("\n--- OPTIMIZED (Pallas flash-attention substitution; "
              "kernel FLOPs analytic) " + "-" * 14)
        print(render_table(opt))
        # pair up improvements
        by_cell = {r["cell"]: r for r in base}
        gains = []
        for r in opt:
            b = by_cell.get(r["cell"].replace("__flash", ""))
            if b and r["t_bound_s"] > 0:
                gains.append(b["t_bound_s"] / r["t_bound_s"])
        if gains:
            import statistics
            print(f"[roofline] flash substitution: median bound speedup "
                  f"{statistics.median(gains):.1f}x over {len(gains)} "
                  f"cells (max {max(gains):.1f}x)")
        fr = [r["roofline_fraction"] for r in opt
              if r.get("roofline_fraction")]
        if fr:
            print(f"[roofline] optimized roofline fraction: median "
                  f"{sorted(fr)[len(fr)//2]*100:.1f}%  max {max(fr)*100:.1f}%")
    write_json("roofline.json", rows)
    return rows


if __name__ == "__main__":
    run()
