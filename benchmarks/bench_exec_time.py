"""Fig 5 — execution time.

Two parts:
 1. MEASURED wall-clock on this CPU: sequential ATA (Strassen-based,
    levels swept) vs classical tril(A^tA) vs classical full A@B, for
    scaled-down sizes (the container is one core; the paper's absolute
    times are replicated analytically in part 2).
 2. MODELED Fig-5 curve: critical-path simulator (paper's process tree +
    its alpha-L + beta-BW comm model) at the paper's n and P grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.ata import ata
from repro.core.strassen import strassen_matmul
from repro.core.cost_model import simulate_metrics, SimParams
from .common import timeit, write_json, PAPER


def run(quick: bool = False):
    rows = []
    ns = (512, 1024) if quick else (512, 1024, 2048)
    for n in ns:
        a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
        t_classical = timeit(jax.jit(
            lambda a: jnp.tril(a.T @ a)), a)
        t_matmul = timeit(jax.jit(lambda a: a.T @ a), a)
        row = {"n": n, "classical_tril_s": t_classical,
               "classical_full_s": t_matmul}
        for lv in (0, 1, 2):
            t = timeit(jax.jit(
                lambda a, lv=lv: ata(a, levels=lv, leaf=128)), a)
            row[f"ata_l{lv}_s"] = t
        t_str = timeit(jax.jit(
            lambda a: strassen_matmul(a.T, a, levels=2, leaf=128)), a)
        row["strassen_ab_s"] = t_str
        rows.append(row)
        print(f"[fig5/measured] n={n}: classical {t_classical*1e3:.1f}ms "
              f"ata(l2) {row['ata_l2_s']*1e3:.1f}ms "
              f"strassenAB {t_str*1e3:.1f}ms")

    model = {}
    for n in PAPER["ns"]:
        sim = simulate_metrics(n, (1,) + PAPER["ps"])
        model[n] = sim
        times = {r["P"]: r["time"] for r in sim["rows"]}
        print(f"[fig5/model] n={n}: T1={sim['t1']:.1f}s "
              f"T250={times[250]:.1f}s (strictly decreasing: "
              f"{all(times[p] >= times[q] - 1e-9 for p, q in zip((1,)+PAPER['ps'], PAPER['ps']))})")
    payload = {"measured": rows, "model": {str(k): v
                                           for k, v in model.items()}}
    write_json("fig5_exec_time.json", payload)
    return payload


if __name__ == "__main__":
    run()
