"""Backward-path trajectory: fused Strassen backward vs dense-dot backward
vs ``jax.grad`` of the reference recursion.

Emits ``BENCH_grads.json`` (artifacts/bench/) so the training half of the
hot path — ``dA = A (S + S^t)``, the VJP of C = tril(A^t A) — is tracked
alongside the forward's BENCH_ata.json.  Per treatment we record:

* wall-clock of ``jax.grad`` (this host; the fused Pallas kernels run
  *interpreted* off-TPU, so absolute times are emulation artifacts —
  tracked for trend only),
* HBM-materialized intermediate bytes of the backward.  Dense-dot /
  reference: measured with ``hbm_intermediate_census`` over the compiled
  HLO (the dense S + S^t buffers, unpack scatters, transposes).  Fused:
  the analytic backward model (``ata_bwd_traffic_model``) — on hardware
  the symm kernel's only HBM temporary is the packed cotangent stack
  (dense entry) or nothing at all (packed entry); the modeled-vs-measured
  comparison for the dense baseline closes the loop on the model's
  baseline term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ata
from repro.kernels.strassen_fused import ata_bwd_traffic_model
from repro.roofline.hlo_census import hbm_intermediate_census
from .common import timeit, write_json

LEVELS = 2


def run(quick: bool = False):
    n = 256 if quick else 512
    block = 64 if quick else 128
    leaf = block // 2          # forces the reference recursion to unroll
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)

    def make_grad(mode, bwd):
        def loss(x):
            c = ata(x, levels=LEVELS, leaf=leaf, mode=mode, bwd=bwd,
                    block=block, out_dtype=jnp.float32)
            return jnp.vdot(w, c)
        return jax.grad(loss)

    treatments = {
        "fused_bwd": make_grad("fused", "fused"),
        "dense_bwd": make_grad("fused", "dense"),
        "reference": make_grad("reference", "fused"),
    }

    bwd_model = ata_bwd_traffic_model(n, n, levels=LEVELS, bk=block,
                                      bn=block, cotangent="dense")
    rows = []
    for name, fn in treatments.items():
        compiled = jax.jit(fn).lower(a).compile()
        wall = timeit(compiled, a, warmup=1, iters=2 if quick else 3)
        census = hbm_intermediate_census(compiled.as_text())
        row = {
            "treatment": name,
            "n": n,
            "levels": LEVELS,
            "block": block,
            "wall_s": wall,
            "census_total_bytes": census["total_bytes"],
        }
        if name == "fused_bwd":
            row["hbm_intermediate_bytes"] = bwd_model["intermediate_bytes"]
            row["hbm_read_bytes"] = bwd_model["read_bytes"]
            row["hbm_write_bytes"] = bwd_model["write_bytes"]
            row["packed_stack_bytes"] = bwd_model["packed_stack_bytes"]
            row["census_is_interpret_emulation"] = (
                jax.default_backend() != "tpu")
        else:
            # the whole grad (fwd + bwd) censused; the bwd share dominates
            # for the dense paths (S + S^t / recursion transposes)
            row["hbm_intermediate_bytes"] = census["total_bytes"]
        rows.append(row)
        print(f"[grads] {name:10s} wall {wall*1e3:8.2f} ms   "
              f"intermediates {row['hbm_intermediate_bytes']/1e6:8.3f} MB")

    by = {r["treatment"]: r for r in rows}
    dense_b = by["dense_bwd"]["hbm_intermediate_bytes"]
    fused_b = by["fused_bwd"]["hbm_intermediate_bytes"]
    modeled_dense = bwd_model["dense_baseline"]["intermediate_bytes"]
    ratio = (dense_b / fused_b) if fused_b else None
    print(f"[grads] bwd HBM intermediates: dense-dot {dense_b/1e6:.3f} MB "
          f"vs fused {fused_b/1e6:.3f} MB "
          f"({'ratio %.1fx' % ratio if ratio else 'fused has none'}; "
          f"acceptance: dense >= 2x fused)")
    print(f"[grads] modeled dense baseline {modeled_dense/1e6:.3f} MB vs "
          f"measured census {dense_b/1e6:.3f} MB (the model counts the "
          f"three logical n^2 buffers; XLA fusion may materialize fewer)")
    payload = {
        "rows": rows,
        "bwd_model": {k: v for k, v in bwd_model.items()
                      if k != "padded_shape"},
        "dense_bwd_intermediate_bytes": dense_b,
        "fused_bwd_intermediate_bytes": fused_b,
        "modeled_dense_baseline_bytes": modeled_dense,
        "intermediate_ratio_dense_over_fused": ratio,
        "acceptance_dense_ge_2x_fused": dense_b >= 2 * fused_b,
    }
    path = write_json("BENCH_grads.json", payload)
    print(f"[grads] wrote {path}")
    return payload


if __name__ == "__main__":
    run()
