"""Fig 6 — speed-up: simulator vs the paper's reported values.

Paper claim: max speed-up 64.28 at P=250, n=10000.
"""
from __future__ import annotations

from repro.core.cost_model import simulate_metrics
from .common import write_json, PAPER


def run(quick: bool = False):
    out = {}
    for n in PAPER["ns"]:
        sim = simulate_metrics(n, PAPER["ps"])
        out[str(n)] = sim["rows"]
        s = {r["P"]: r["speedup"] for r in sim["rows"]}
        print(f"[fig6] n={n}: " + " ".join(
            f"S({p})={s[p]:.2f}" for p in PAPER["ps"]))
    s250 = out["10000"][-1]["speedup"]
    err = abs(s250 - PAPER["max_speedup"]) / PAPER["max_speedup"]
    print(f"[fig6] paper max speed-up {PAPER['max_speedup']} @P=250 "
          f"vs model {s250:.2f} (rel err {err:.1%})")
    assert err < 0.15, "speed-up model drifted from the paper's figure"
    write_json("fig6_speedup.json", out)
    return out


if __name__ == "__main__":
    run()
