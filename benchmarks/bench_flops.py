"""§3.1 complexity claim — exact multiplication counts of Algorithm 1 vs
the paper's (2/7) n^{log2 7} bound and the classical n^2(n+1)/2 count."""
from __future__ import annotations

from repro.core.cost_model import (ata_mults_exact, ata_mults_bound,
                                   classical_ata_mults,
                                   strassen_mults_exact, strassen_mults)
from .common import write_json


def run(quick: bool = False):
    rows = []
    ns = (256, 512, 1024, 2048, 4096) if quick else \
        (256, 512, 1024, 2048, 4096, 8192)
    for n in ns:
        exact = ata_mults_exact(n, n, leaf=32)
        bound = ata_mults_bound(n)
        classical = classical_ata_mults(n)
        strassen_full = strassen_mults_exact(n, n, n, leaf=32)
        rows.append({"n": n, "ata_exact": exact, "bound_2_7_nlog7": bound,
                     "classical_tril": classical,
                     "strassen_full_ab": strassen_full,
                     "ata_vs_classical": exact / classical,
                     "ata_vs_strassen_ab": exact / strassen_full})
        print(f"[s3.1] n={n:>5}: ATA {exact:.3e} | (2/7)n^lg7 {bound:.3e} "
              f"| classical {classical:.3e} | ATA/classical "
              f"{exact/classical:.3f} | ATA/StrassenAB "
              f"{exact/strassen_full:.3f}")
    # asymptotic ratio ATA/bound must approach <= 3.5 (the bound counts
    # only the leading term; with leaf=32 the leaf grams add a constant);
    # ATA must beat classical for large n and halve Strassen-AB.
    last = rows[-1]
    assert last["ata_vs_classical"] < 1.0, "ATA should beat classical"
    assert 0.4 < last["ata_vs_strassen_ab"] < 0.75, \
        "symmetry should save ~half of a generic Strassen A@B"
    # rectangular sanity
    for (m, n) in ((4096, 1024), (1024, 4096)):
        e = ata_mults_exact(m, n, leaf=32)
        c = classical_ata_mults(n, m)
        print(f"[s3.1] rect {m}x{n}: ATA {e:.3e} vs classical {c:.3e} "
              f"ratio {e/c:.3f}")
    write_json("s31_flops.json", rows)
    return rows


if __name__ == "__main__":
    run()
