"""Fig 7 — efficiency = S/P. Paper: 0.66 (P=6) .. 0.26 (P=250), with a
local RISE at P=38 (two complete parallel levels) — both reproduced."""
from __future__ import annotations

from repro.core.cost_model import simulate_metrics
from .common import write_json, PAPER


def run(quick: bool = False):
    out = {}
    for n in PAPER["ns"]:
        rows = simulate_metrics(n, PAPER["ps"])["rows"]
        out[str(n)] = rows
        e = {r["P"]: r["efficiency"] for r in rows}
        print(f"[fig7] n={n}: " + " ".join(
            f"E({p})={e[p]:.3f}" for p in PAPER["ps"]))
        # the paper's §6.2 observation: efficiency *grows* at P=38
        assert e[38] > e[18], "P=38 complete-level efficiency rise missing"
    e6 = out["10000"][0]["efficiency"]
    e250 = out["10000"][-1]["efficiency"]
    assert abs(e6 - PAPER["efficiency_p6"]) < 0.08, e6
    assert abs(e250 - PAPER["efficiency_p250"]) < 0.08, e250
    print(f"[fig7] endpoints: E(6)={e6:.3f} (paper 0.66), "
          f"E(250)={e250:.3f} (paper 0.26)")
    write_json("fig7_efficiency.json", out)
    return out


if __name__ == "__main__":
    run()
