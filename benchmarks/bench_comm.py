"""§5 communication model — latency L(n,P), bandwidth BW(n,P), and the
paper's §6.3.2 claim that communication is 0.14%..0.46% of total time."""
from __future__ import annotations

from repro.core.cost_model import (latency_messages, bandwidth_words,
                                   comm_time, lmax, npl, simulate_ata_p,
                                   SimParams)
from .common import write_json, PAPER


def run(quick: bool = False):
    sp = SimParams()
    rows = []
    for n in PAPER["ns"]:
        for p in PAPER["ps"]:
            L = latency_messages(p)
            bw = bandwidth_words(n)
            tc = comm_time(n, p, sp.alpha, sp.beta)
            total = simulate_ata_p(n, p, sp)
            frac = tc / total
            rows.append({"n": n, "P": p, "lmax": lmax(p), "L_msgs": L,
                         "BW_words": bw, "comm_s": tc, "total_s": total,
                         "comm_fraction": frac})
    for r in rows:
        if r["n"] == 10000:
            print(f"[s5] P={r['P']:>3} lmax={r['lmax']} L={r['L_msgs']:>2} "
                  f"comm {r['comm_s']*1e3:6.1f}ms of {r['total_s']:7.2f}s "
                  f"({r['comm_fraction']:.2%})")
    fr = [r["comm_fraction"] for r in rows]
    print(f"[s5] comm fraction range {min(fr):.2%}..{max(fr):.2%} "
          f"(paper: 0.14%..0.46%)")
    # same order of magnitude as the paper's measured percentages
    assert max(fr) < 0.02, "communication should be a sub-2% fraction"
    # npl sanity against the paper's complete-level process counts
    assert [npl(l) for l in (0, 1, 2, 3)] == [1, 6, 38, 250]
    write_json("s5_comm.json", rows)
    return rows


if __name__ == "__main__":
    run()
