"""Distributed-gram schemes: modeled vs measured communication volume.

The multi-device run needs ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` set before jax initializes, so the work happens in a
child process (``benchmarks._distributed_child``; same pattern as the
``multidevice`` pytest marker) which writes ``BENCH_distributed.json``:

* per (scheme x shape): closed-form per-device wire bytes / message
  rounds from ``core.cost_model.gram_comm_cost`` next to a
  ``collective_census`` of the actually-compiled post-SPMD HLO, + wall
  clock on the 8 fake devices;
* the allreduce-vs-ring crossover between a tall-skinny and a wide
  shape, asserted to flip identically in the model and the measurement —
  the evidence that ``distributed_gram(scheme="auto")`` ranks schemes on
  a model the compiled programs actually obey.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def run(quick: bool = False):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks._distributed_child"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, env=env, cwd=REPO, capture_output=True,
                         text=True, timeout=1200)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise RuntimeError("bench_distributed child failed")
    assert "ALL_OK" in out.stdout
    return str(REPO / "artifacts" / "bench" / "BENCH_distributed.json")


if __name__ == "__main__":
    run("--quick" in sys.argv)
