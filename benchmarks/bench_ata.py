"""ATA hot-path trajectory: fused schedule vs reference recursion vs jnp.dot.

Emits ``BENCH_ata.json`` (artifacts/bench/) so the perf trajectory of the
single hottest path in the repo — C = tril(A^t A) — is tracked from this
PR onward.  Per treatment we record:

* wall-clock (this host; the fused Pallas kernel runs *interpreted* off-TPU,
  so its absolute time is an emulation artifact — tracked for trend only),
* HBM-materialized intermediate bytes.  Reference/dot: measured with
  ``roofline.hlo_census.hbm_intermediate_census`` over the compiled HLO
  (what XLA actually materializes: operand sums, Strassen M_i products,
  pad/concatenate copies).  Fused: the analytic kernel model
  (``strassen_fused.ata_traffic_model``) — on hardware the kernel writes
  only the packed output, with no HBM temporaries beyond an optional
  pad copy; the raw census of the interpret-mode *emulation* is reported
  alongside for transparency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import ata
from repro.core.cost_model import ir_leaf_count, pipelined_bytes_score
from repro.core.leaf_ir import compile_program
from repro.gram.verify import default_rtol, freivalds_gram
from repro.kernels.strassen_fused import (aat_traffic_model,
                                          ata_traffic_model,
                                          rank_k_traffic_model)
from repro.kernels import ops
from repro.roofline.hlo_census import hbm_intermediate_census
from .common import timeit_detail, write_json

LEVELS = 2

# Treatments whose hot loop is the generic Pallas kernel: off-TPU these
# run in interpret mode, so their wall clocks are emulation artifacts —
# stamped interpret=True and EXCLUDED from compiled_wall_rows and every
# acceptance key (ISSUE 10).  dot/reference treatments compile natively
# on every backend.
_PALLAS_TREATMENTS = frozenset((
    "fused", "fused_pd1", "fused_pd2", "fused_fp8", "aat_fused",
    "rank_k_fused", "rank_k_delta"))


def _is_interpret(name: str) -> bool:
    return (name in _PALLAS_TREATMENTS
            and jax.default_backend() != "tpu")


def _rank_k_zero_stack(n, block):
    t = -(-n // block)
    return jnp.zeros((t * (t + 1) // 2 * block, block), jnp.float32)


def run(quick: bool = False):
    n = 256 if quick else 512
    block = 64 if quick else 128
    leaf = block // 2          # forces the reference recursion to unroll
    a = jax.random.normal(jax.random.PRNGKey(0), (n, n), jnp.float32)

    stack0 = _rank_k_zero_stack(n, block)

    def rank_k_fused(x):
        # ONE accumulating kernel: the state seeds the VMEM accumulator
        from repro.kernels.strassen_fused import fused_rank_k_update
        return fused_rank_k_update(stack0, x, levels=LEVELS, bk=block)

    def rank_k_delta_baseline(x):
        # status quo (PR 2-4 streamed update): compute the delta stack,
        # then add it into the state — two HBM round trips of the stack
        delta = ops.ata_fused_packed(x, levels=LEVELS, bk=block, bn=block,
                                     out_dtype=jnp.float32)
        return stack0 + delta

    treatments = {
        "dot": lambda x: jnp.tril(
            jnp.dot(x.T, x, preferred_element_type=jnp.float32)),
        "reference": lambda x: ata(x, levels=LEVELS, leaf=leaf,
                                   mode="reference"),
        "fused": lambda x: ops.ata_fused_packed(x, levels=LEVELS, bk=block,
                                                bn=block),
        # the pipelined hot loop (ISSUE 10): depth=1 is the unpipelined
        # schedule, depth=2 double-buffers the tile DMAs; bit-exact pair
        "fused_pd1": lambda x: ops.ata_fused_packed(
            x, levels=LEVELS, bk=block, bn=block, pipeline_depth=1),
        "fused_pd2": lambda x: ops.ata_fused_packed(
            x, levels=LEVELS, bk=block, bn=block, pipeline_depth=2),
        # fp8 operand tiles, fp32 accumulation — halves(+) the DMA read
        # term; parity is gated by the Freivalds probe below, not here
        "fused_fp8": lambda x: ops.ata_fused_packed(
            x, levels=LEVELS, bk=block, bn=block,
            operand_dtype="float8_e4m3fn"),
        # the two new leaf-IR programs, tracked from day one:
        # row gram (aat) — fused vs reference recursion vs jnp.dot
        "aat_dot": lambda x: jnp.tril(
            jnp.dot(x, x.T, preferred_element_type=jnp.float32)),
        "aat_reference": lambda x: ata(x, gram_of="rows", levels=LEVELS,
                                       leaf=leaf, mode="reference"),
        "aat_fused": lambda x: ops.aat_fused_packed(x, levels=LEVELS,
                                                    bm=block, bk=block),
        # accumulating rank-k update — the fused single-kernel C += A^tA
        # vs the status-quo streamed update (delta stack + add) vs dot
        "rank_k_dot": lambda x: jnp.tril(
            jnp.dot(x.T, x, preferred_element_type=jnp.float32)),
        "rank_k_delta": rank_k_delta_baseline,
        "rank_k_fused": rank_k_fused,
    }

    backend = jax.default_backend()
    rows = []
    for name, fn in treatments.items():
        # one compilation per treatment serves both the timing and the
        # census (interpret-mode Pallas lowering is the expensive step)
        compiled = jax.jit(fn).lower(a).compile()
        detail = timeit_detail(compiled, a,
                               iters=5 if quick else 7)
        wall = detail["wall_s"]
        census = hbm_intermediate_census(compiled.as_text())
        row = {
            "treatment": name,
            "n": n,
            "levels": LEVELS,
            "block": block,
            "wall_s": wall,
            "reps": detail["reps"],
            "warmup": detail["warmup"],
            "backend": backend,
            "interpret": _is_interpret(name),
            "census_total_bytes": census["total_bytes"],
            "census_by_opcode": census["by_opcode"],
        }
        if name in ("fused", "fused_pd1", "fused_pd2", "fused_fp8",
                    "aat_fused", "rank_k_fused"):
            if name.startswith("fused"):
                in_b = 1 if name == "fused_fp8" else 4
                model = ata_traffic_model(n, n, levels=LEVELS, bk=block,
                                          bn=block, in_bytes=in_b)
            elif name == "aat_fused":
                model = aat_traffic_model(n, n, levels=LEVELS, bm=block,
                                          bk=block)
            else:
                model = rank_k_traffic_model(n, n, levels=LEVELS, bk=block,
                                             bn=block)
            row["hbm_intermediate_bytes"] = model["intermediate_bytes"]
            row["hbm_write_bytes"] = model["write_bytes"]
            row["hbm_read_bytes"] = model["read_bytes"]
            row["model_flops"] = model["flops"]
            row["model_grid_steps"] = model["grid_steps"]
            row["census_is_interpret_emulation"] = row["interpret"]
        else:
            row["hbm_intermediate_bytes"] = census["total_bytes"]
        rows.append(row)
        tag = "emul" if row["interpret"] else backend
        print(f"[ata] {name:10s} wall {wall*1e3:8.2f} ms ({tag})  "
              f"intermediates {row['hbm_intermediate_bytes']/1e6:8.3f} MB")

    by = {r["treatment"]: r for r in rows}
    ref_b = by["reference"]["hbm_intermediate_bytes"]
    fus_b = by["fused"]["hbm_intermediate_bytes"]
    # Tile-aligned shapes give the fused kernel literally zero HBM
    # intermediates, so a ratio would be a meaningless magnitude; record
    # the raw byte counts (the trackable trajectory) and a ratio only
    # when the denominator is real.
    ratio = (ref_b / fus_b) if fus_b else None
    print(f"[ata] HBM intermediates: reference {ref_b/1e6:.3f} MB vs "
          f"fused {fus_b/1e6:.3f} MB "
          f"({'ratio %.1fx' % ratio if ratio else 'fused has none'}; "
          f"acceptance: reference >= 2x fused)")
    # the new leaf-IR programs' trajectories: aat (row gram) and the
    # accumulating rank-k update, fused vs their baselines
    aat_ref_b = by["aat_reference"]["hbm_intermediate_bytes"]
    aat_fus_b = by["aat_fused"]["hbm_intermediate_bytes"]
    rk_model = rank_k_traffic_model(n, n, levels=LEVELS, bk=block, bn=block)
    rk_base = rk_model["baseline"]

    # -- the algebra axis: per-(variant, gram) leaf counts + parity ------
    # The mult-count deliverable of the gram-algebra registry: at equal
    # levels the dps recursion G(l) = 2G(l-1) + 3t^(l-1) does fewer leaf
    # products than the paper's 4G(l-1) + 2t^(l-1), with fused parity.
    want = np.tril(np.asarray(a, np.float64).T @ np.asarray(a, np.float64))
    scale = max(np.abs(want).max(), 1.0)
    variant_rows = []
    # (winograd, dps) is excluded: its levels=2 operand fan-in exceeds
    # MAX_OPERAND_TERMS, so the executor clamps the depth and the row's
    # closed-form counts would describe a program the kernel did not run
    for variant, gram in (("strassen", "strassen"), ("strassen", "dps"),
                          ("winograd", "strassen")):
        fn = lambda x: ops.ata_fused(x, levels=LEVELS, variant=variant,
                                     gram=gram, bk=block, bn=block)
        compiled = jax.jit(fn).lower(a).compile()
        detail = timeit_detail(compiled, a)
        wall = detail["wall_s"]
        err = float(np.abs(np.asarray(compiled(a), np.float64)
                           - want).max() / scale)
        prog = compile_program("ata", LEVELS, variant, gram=gram)
        row = {
            "treatment": f"ata_{variant}_{gram}",
            "variant": variant,
            "gram": gram,
            "n": n,
            "levels": LEVELS,
            "leaf_count": ir_leaf_count("ata", LEVELS, variant, gram=gram),
            "mult_count_at_block": prog.mult_count(block, block),
            "wall_s": wall,
            "reps": detail["reps"],
            "warmup": detail["warmup"],
            "backend": backend,
            "interpret": backend != "tpu",    # all variant rows are Pallas
            "parity_max_rel_err": err,
            "parity_ok": err < 1e-5,
        }
        variant_rows.append(row)
        print(f"[ata] {row['treatment']:22s} leaves {row['leaf_count']:4d} "
              f"wall {wall*1e3:8.2f} ms   err {err:.2e}")
    vby = {(r["variant"], r["gram"]): r for r in variant_rows}
    dps_below = (vby[("strassen", "dps")]["leaf_count"]
                 < vby[("strassen", "strassen")]["leaf_count"])
    print(f"[ata] dps leaf count below strassen-gram at levels={LEVELS}: "
          f"{dps_below}")

    # -- pipelining acceptance (ISSUE 10) --------------------------------
    # On TPU the pd1/pd2 rows are real compiled wall clocks and the gate
    # is wall-based: depth-2 must be no worse than 1.05x depth-1.  Off-TPU
    # the rows are interpret-mode emulation — the emulator serializes the
    # DMA bookkeeping the real pipeline overlaps, so an emulated wall gate
    # would always fail for the wrong reason.  There the gate falls back
    # to the roofline model (pipelined_bytes_score) on the same traffic,
    # and pipeline_acceptance_basis records which basis produced the bit.
    pd1, pd2 = by["fused_pd1"], by["fused_pd2"]
    if not pd1["interpret"] and not pd2["interpret"]:
        basis = "compiled_wall"
        pipe_ok = pd2["wall_s"] <= 1.05 * pd1["wall_s"]
    else:
        basis = "model_score"
        s1 = pipelined_bytes_score(
            pd1["hbm_read_bytes"], pd1["hbm_write_bytes"],
            pd1["model_flops"], pipeline_depth=1,
            grid_steps=pd1["model_grid_steps"])
        s2 = pipelined_bytes_score(
            pd2["hbm_read_bytes"], pd2["hbm_write_bytes"],
            pd2["model_flops"], pipeline_depth=2,
            grid_steps=pd2["model_grid_steps"])
        pipe_ok = s2 <= 1.05 * s1
    print(f"[ata] pipeline acceptance ({basis}): depth-2 no worse than "
          f"1.05x depth-1: {pipe_ok}")

    # fp8 operand serve parity: the quantized Gram must still satisfy the
    # Freivalds identity at the precision-scaled tolerance — this is the
    # end-to-end check that quantize-after-pad + fp32 accumulation did
    # not silently corrupt the output.
    fp8_c = np.asarray(
        ops.ata_fused(a, levels=LEVELS, bk=block, bn=block,
                      operand_dtype="float8_e4m3fn"))
    fp8_ok, fp8_err = freivalds_gram(
        np.asarray(a), fp8_c, probes=4, full=False,
        rtol=default_rtol("float8_e4m3fn"))
    print(f"[ata] fp8 freivalds at n={n}: ok={fp8_ok} "
          f"rel_err={fp8_err:.3e} (rtol "
          f"{default_rtol('float8_e4m3fn'):.2e})")

    # compiled (non-interpret) wall clocks only — the rows a perf trend
    # may legitimately be built on.  Off-TPU this keeps dot/reference and
    # drops every emulated Pallas row.
    compiled_wall_rows = [
        {k: r[k] for k in ("treatment", "n", "levels", "block", "wall_s",
                           "reps", "warmup", "backend")}
        for r in rows + variant_rows if not r["interpret"]]

    payload = {
        "rows": rows,
        "reference_intermediate_bytes": ref_b,
        "fused_intermediate_bytes": fus_b,
        "intermediate_ratio_ref_over_fused": ratio,
        "acceptance_ref_ge_2x_fused": ref_b >= 2 * fus_b,
        "aat_reference_intermediate_bytes": aat_ref_b,
        "aat_fused_intermediate_bytes": aat_fus_b,
        "aat_acceptance_ref_ge_2x_fused": aat_ref_b >= 2 * aat_fus_b,
        "rank_k_modeled_total_bytes": (
            rk_model["read_bytes"] + rk_model["write_bytes"]
            + rk_model["intermediate_bytes"]),
        "rank_k_baseline_total_bytes": (
            rk_base["read_bytes"] + rk_base["write_bytes"]
            + rk_base["intermediate_bytes"]),
        "variant_rows": variant_rows,
        "acceptance_dps_leaf_count_below_strassen": dps_below,
        "acceptance_variant_parity": all(r["parity_ok"]
                                         for r in variant_rows),
        "backend": backend,
        "compiled_wall_rows": compiled_wall_rows,
        "pipeline_acceptance_basis": basis,
        "acceptance_pipeline_no_worse": bool(pipe_ok),
        "fp8_freivalds_rel_err": fp8_err,
        "fp8_freivalds_rtol": default_rtol("float8_e4m3fn"),
        "acceptance_fp8_freivalds": bool(fp8_ok),
    }
    path = write_json("BENCH_ata.json", payload)
    print(f"[ata] wrote {path}")
    # separate trend artifact: compiled walls only, one small file a CI
    # run can diff/plot across commits without parsing the full payload
    trend = write_json("BENCH_ata_compiled_wall.json", {
        "backend": backend,
        "rows": compiled_wall_rows,
    })
    print(f"[ata] wrote {trend}")
    return payload


if __name__ == "__main__":
    run()
